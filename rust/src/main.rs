//! `repro` — the leader CLI for the Bayesian-RNN-on-FPGA reproduction.
//!
//! Subcommands:
//!   sweep   run the algorithmic DSE sweep, write the lookup table
//!   dse     run the optimisation framework over a lookup table (Tables V/VI)
//!   train   train one architecture (native engine or PJRT AOT train step)
//!   eval    evaluate a trained checkpoint (float / fixed-point FPGA sim)
//!   serve   run the serving coordinator on synthetic ECG traffic
//!           (--adaptive-mc switches to early-exit sequential sampling)
//!   uq      uncertainty-quantification pipeline: calibrate / evaluate /
//!           report (docs/uncertainty.md)
//!   info    show artifact manifest + platform
//!
//! Arg parsing is hand-rolled (`--key value` / flags) — no clap in this
//! offline environment (see Cargo.toml).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};
use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::coordinator::loadgen::PoissonTrace;
use bayes_rnn_fpga::coordinator::{
    run_open_loop, run_stream_open_loop, AdaptiveTicket, BatchPolicy,
    Engine, FaultPlan, Fleet, FleetConfig, FleetError, OpenLoopOutcome,
    RouterPolicy, ScenarioSpec, Ticket, DEFAULT_QUEUE_DEPTH,
};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::{reuse_search, reuse_search_q};
use bayes_rnn_fpga::dse::{LookupTable, Optimizer};
use bayes_rnn_fpga::fixedpoint::Precision;
use bayes_rnn_fpga::fpga::accel::Accelerator;
use bayes_rnn_fpga::hwmodel::ZC706;
use bayes_rnn_fpga::jsonio::{self, Json};
use bayes_rnn_fpga::kernels::{self, KernelBackend, MaskBank};
use bayes_rnn_fpga::nn::model::Model;
use bayes_rnn_fpga::nn::Params;
use bayes_rnn_fpga::obs::{
    self, push_slo_metrics, push_timeline_metrics, serve_metric_set,
    serve_obs_json, FaultStats, LogHistogram, ObsConfig, SloReport,
    SloSpec, Timeline, TraceLog,
};
use bayes_rnn_fpga::rng::Rng;
use bayes_rnn_fpga::runtime::Runtime;
use bayes_rnn_fpga::tensor::{load_tensors, save_tensors, Tensor};
use bayes_rnn_fpga::train::eval::{eval_anomaly, eval_classify, ModelPredictor};
use bayes_rnn_fpga::train::sweep::{self, SweepOpts};
use bayes_rnn_fpga::train::{NativeTrainer, PjrtTrainer, TrainOpts};
use bayes_rnn_fpga::uq::{
    AdaptiveMcConfig, OodScorer, RiskPolicy, RiskTier, TemperatureScaler,
    UqCollector, UqReport,
};

/// Tiny `--key value` parser: positional tokens (subcommand and, for
/// `uq`, its action) + options.
struct Args {
    opts: HashMap<String, String>,
    pos: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> (Option<String>, Args) {
        let mut opts = HashMap::new();
        let mut pos = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    opts.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                pos.push(a.clone());
                i += 1;
            }
        }
        let cmd = pos.first().cloned();
        (cmd, Args { opts, pos })
    }

    /// Positional token `i` (0 = the subcommand itself).
    fn positional(&self, i: usize) -> Option<&str> {
        self.pos.get(i).map(|s| s.as_str())
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    fn task(&self) -> Result<Task> {
        self.get("task")
            .unwrap_or("classify")
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))
    }

    fn artifacts_dir(&self) -> PathBuf {
        PathBuf::from(self.get("artifacts").unwrap_or("artifacts"))
    }

    /// `--precision q8|q12|q16[,l<i>=<fmt>...]` (default the paper's
    /// q16).
    fn precision(&self) -> Result<Precision> {
        match self.get("precision") {
            Some(s) => {
                Precision::parse(s).map_err(|e| anyhow::anyhow!(e))
            }
            None => Ok(Precision::q16()),
        }
    }
}

/// A submitted request on either serving path.
enum AnyTicket {
    Fixed(Ticket),
    Adaptive(AdaptiveTicket),
}

/// Parse the shared adaptive-UQ flags (`--s-min --target-ci --chunk
/// --abstain-entropy --defer-entropy --max-epistemic --calibration`)
/// into the controller envelope and risk policy. An explicit
/// `--calibration PATH` must be readable (hard error); `default_cal`
/// is tried opportunistically with a fallback note, identity otherwise.
fn uq_flags(
    args: &Args,
    s_max: usize,
    default_cal: Option<PathBuf>,
) -> Result<(AdaptiveMcConfig, RiskPolicy)> {
    anyhow::ensure!(s_max >= 1, "--samples must be >= 1");
    let mc = AdaptiveMcConfig {
        s_min: args.usize_or("s-min", 4).clamp(1, s_max),
        s_max,
        target_ci: args.f64_or("target-ci", 0.02),
        z: 1.96,
        chunk: args.usize_or("chunk", 4).max(1),
    };
    let scaler = match args.get("calibration") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading calibration {path}"))?;
            TemperatureScaler::from_json(&text)?
        }
        None => match &default_cal {
            Some(p) => match std::fs::read_to_string(p) {
                Ok(text) => TemperatureScaler::from_json(&text)?,
                Err(_) => {
                    eprintln!(
                        "note: no calibration at {} (run `repro uq \
                         calibrate`); using T = 1",
                        p.display()
                    );
                    TemperatureScaler::identity()
                }
            },
            None => TemperatureScaler::identity(),
        },
    };
    let risk = RiskPolicy {
        abstain_entropy: args.f64_or("abstain-entropy", 0.9),
        defer_entropy: args.f64_or("defer-entropy", 0.5),
        ood: OodScorer::with_threshold(args.f64_or("max-epistemic", 0.15)),
        scaler,
    };
    Ok((mc, risk))
}

/// Parse "anomaly_h16_nl2_YNYN"-style names back into a config.
fn parse_arch(name: &str) -> Result<ArchConfig> {
    let parts: Vec<&str> = name.split('_').collect();
    anyhow::ensure!(parts.len() == 4, "arch name like anomaly_h16_nl2_YNYN");
    let task: Task =
        parts[0].parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let h: usize = parts[1].trim_start_matches('h').parse()?;
    let nl: usize = parts[2].trim_start_matches("nl").parse()?;
    Ok(ArchConfig::new(task, h, nl, parts[3]))
}

fn print_usage() {
    eprintln!(
        "repro — Bayesian-RNN-on-FPGA reproduction CLI

usage: repro <subcommand> [--key value | --flag] ...

subcommands:
  sweep   run the algorithmic DSE sweep, write the lookup table
          (each point also gains accuracy@q8/q12/q16 fixed-point columns)
          [--task anomaly|classify] [--full] [--epochs N]
          [--train-subset N] [--test-subset N] [--samples S]
          [--quant-subset N] [--out PATH]
  dse     optimise over a lookup table (Tables V/VI); searches the
          8/12/16-bit precision axis and reports the chosen format,
          its resources and the quantised accuracy (docs/quantization.md)
          [--task T] [--lookup PATH] [--batch N] [--samples S]
          [--precision q8|q12|q16]  (restrict the search to one format)
  train   train one architecture
          --arch NAME [--backend native|pjrt] [--epochs N] [--batch N]
          [--lr F] [--seed N] [--out PATH]
  eval    evaluate a trained checkpoint (float / --fixed FPGA sim)
          --arch NAME [--weights PATH] [--samples S] [--test-subset N]
          [--fixed] [--precision q8|q12|q16[,l<i>=FMT...]]
  serve   run the serving fleet on synthetic ECG traffic
          [--arch NAME] [--engines N]
          [--router rr|least-loaded|mc-shard|affinity]
          [--backend fpga|gpu|pjrt|mix] [--samples S] [--requests N]
          [--rate REQ_PER_S] [--queue-depth N] [--batch N] [--shed]
          [--seed N] [--json] [--kernel scalar|blocked|simd|parallel]
          streaming sessions (docs/serving.md §Streaming sessions):
          [--stream C]  (serve each request as a session whose signal
           arrives in C chunks against resident MC lane state — each
           decision costs O(chunk), bitwise equal to one continuous
           pass; fpga backend, classify task)
          [--stream-beats B] (beats per session signal, default 4)
          [--session-mb N]  (resident lane-state byte budget, default
           8; evicted sessions rebuild transparently by replay)
          [--mask-bank-mb N]  (share a seed-indexed bitplane-mask cache
           across engines — docs/kernels.md §Mask bank; 0 = off,
           the default, and output bits never change either way)
          fault injection (docs/serving.md §Fault tolerance):
          [--chaos PLAN]  (seeded deterministic fault plan, e.g.
           \"kill=e1@250ms,stall=e2@100ms+50ms,drop=0.01\"; the fleet
           re-dispatches orphaned shards, hedges stragglers and
           re-pins sessions — merged outputs stay bit-identical)
          [--wait-timeout-ms F]  (surface lost replies as a typed
           degraded error instead of waiting the full default)
          [--obs] [--metrics PATH] [--trace PATH] [--window-ms F]
          [--slo latency_ms=F,target=F,max_shed=F] [--slo-gate]
          (--obs adds per-stage latency histograms + engine health to
           the output; --metrics writes metrics JSON to PATH and
           Prometheus text to PATH.prom; --trace streams JSONL stage
           events; any of them implies --obs — docs/observability.md.
           With obs on, the run is also sliced into --window-ms
           timeline windows and evaluated against the SLO; --slo-gate
           exits non-zero when the SLO fails, for CI)
          [--precision q8|q12|q16[,l<i>=FMT...]]  (fpga backend only;
           every engine runs at the one given format)
          (--kernel selects the MVM backend — docs/kernels.md
           §Backends; REPRO_KERNEL sets the default. All backends
           emit bit-identical outputs; scalar additionally forces the
           legacy per-sample FPGA-sim loop, the bench baseline)
          adaptive MC (docs/uncertainty.md): [--adaptive-mc]
          [--target-ci F] [--s-min N] [--chunk N] [--abstain-entropy F]
          [--defer-entropy F] [--max-epistemic F] [--calibration PATH]
          (missing weights fall back to a deterministic random init —
           synthetic load mode, used by the bench harness)
  loadgen open-loop scenario runner: seeded Poisson arrivals replayed
          against a fleet with coordinated-omission-correct latency
          (e2e measured from each request's *scheduled* arrival) and
          offered-vs-achieved per timeline window
          --scenario baseline|fan_out|fan_in|scaling|poisson_mix|
                     stream_monitor
          [--arch NAME] [--engines N] [--rate REQ_PER_S] [--requests N]
          [--samples S] [--seed N] [--backend fpga|gpu|pjrt]
          [--queue-depth N] [--shed] [--batch N] [--window-ms F]
          [--slo SPEC] [--slo-gate] [--json] [--metrics PATH]
          [--trace PATH] [--kernel K] [--precision P] [--mask-bank-mb N]
          [--chaos PLAN] [--wait-timeout-ms F]  (deterministic fault
           injection — docs/serving.md §Fault tolerance)
          stream_monitor only: [--sessions N] [--session-mb N]
          (chunks arrive open-loop round-robin over N resident
           streaming sessions — docs/serving.md §Streaming sessions)
          (observability is always on here — docs/observability.md
           §Open-loop)
  uq      uncertainty-quantification pipeline (classify task)
          uq calibrate  fit temperature scaling offline
                        [--arch NAME] [--samples S] [--subset N]
                        [--out PATH] [--json]
          uq evaluate   run the adaptive controller + risk tiers
                        [--arch NAME] [--samples S] [--subset N]
                        [--target-ci F] [--s-min N] [--chunk N]
                        [--abstain-entropy F] [--defer-entropy F]
                        [--max-epistemic F] [--calibration PATH]
                        [--out PATH] [--json]
          uq report     render a saved evaluation report
                        [--file PATH] [--json]
  info    show artifact manifest + platform
  help    this message (also: --help on any subcommand)

common flags: --artifacts DIR (default ./artifacts), --weights PATH"
    );
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, args) = Args::parse(&argv);
    if args.flag("help") {
        print_usage();
        return Ok(());
    }
    match cmd.as_deref() {
        Some("sweep") => cmd_sweep(&args),
        Some("dse") => cmd_dse(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("uq") => cmd_uq(&args),
        Some("info") => cmd_info(&args),
        Some("help") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            print_usage();
            anyhow::bail!("unknown subcommand {other:?}");
        }
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let task = args.task()?;
    let opts = SweepOpts {
        full_grid: args.flag("full"),
        epochs: args.usize_or("epochs", 25),
        train_subset: args.usize_or("train-subset", 500),
        test_subset: args.usize_or("test-subset", 400),
        mc_samples: args.usize_or("samples", 10),
        // Per-precision fixed-point eval window (0 skips the
        // accuracy@q8/q12/q16 lookup columns).
        quant_subset: args.usize_or("quant-subset", 64),
        ..Default::default()
    };
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("lookup_{}.json", task.as_str()))
    });
    let mut table = if let Ok(t) = LookupTable::load(&out) {
        println!("extending existing table {}", out.display());
        t
    } else {
        LookupTable::new()
    };
    let t0 = std::time::Instant::now();
    sweep::run(task, &opts, &mut table, |done, total, name| {
        println!("[{done}/{total}] {name}");
    });
    table.save(&out)?;
    println!(
        "sweep done in {:.1}s -> {} ({} entries)",
        t0.elapsed().as_secs_f64(),
        out.display(),
        table.entries.len()
    );
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let task = args.task()?;
    let path = args.get("lookup").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("lookup_{}.json", task.as_str()))
    });
    let lookup = LookupTable::load(&path).with_context(|| {
        format!("run `repro sweep --task {}` first", task.as_str())
    })?;
    let mut opt = Optimizer::new(&ZC706, &lookup);
    opt.batch = args.usize_or("batch", 50);
    opt.mc_samples = args.usize_or("samples", 30);
    if args.get("precision").is_some() {
        // Restrict the Q axis to one format.
        opt.precisions = vec![args.precision()?];
    }
    println!(
        "{:<14} {:>20} {:>12} {:>5} {:>4} {:>11} {:>11} {:>6} {:>7}  metrics",
        "Mode", "A:{H,NL,B}", "R:{x,h,d}", "Q", "S", "FPGA [ms]",
        "GPU [ms]", "DSP", "P [W]"
    );
    let mut chosen = Vec::new();
    for mode in Optimizer::modes_for(task) {
        match opt.optimize(task, mode) {
            Some(c) => {
                // Float metrics, plus the quantised column backing the
                // choice when one was measured.
                let mut metr: Vec<String> = c
                    .metrics
                    .iter()
                    .filter(|(k, _)| !k.contains('@'))
                    .map(|(k, v)| format!("{k}={v:.3}"))
                    .collect();
                for m in ["accuracy", "auc", "ap"] {
                    if let Some(v) = c.quant_metric(m) {
                        metr.push(format!(
                            "{m}@{}={v:.3}",
                            c.precision.name()
                        ));
                    }
                }
                let delta = c
                    .dsp_delta_vs_q16_pct()
                    .map(|d| format!(" ({d:+.0}% vs q16)"))
                    .unwrap_or_else(|| " (q16 infeasible)".into());
                println!(
                    "{:<14} {:>20} {:>12} {:>5} {:>4} {:>11.2} {:>11.2} \
                     {:>6.0} {:>7.2}  {}{}",
                    c.mode,
                    format!(
                        "{{{},{},{}}}",
                        c.arch.hidden,
                        c.arch.nl,
                        c.arch.bayes_str()
                    ),
                    format!(
                        "{{{},{},{}}}",
                        c.reuse.rx, c.reuse.rh, c.reuse.rd
                    ),
                    c.precision.name(),
                    c.s,
                    c.fpga_latency_ms,
                    c.gpu_latency_ms,
                    c.resources.dsps,
                    c.fpga_watts,
                    metr.join(" "),
                    if c.precision.name() == "q16" {
                        String::new()
                    } else {
                        delta
                    },
                );
                chosen.push(c);
            }
            None => {
                println!("{:<14} (no feasible configuration)", mode.name())
            }
        }
    }
    // Precision axis detail for each winning architecture: per-format
    // resource estimate, modelled latency and quantised accuracy.
    for c in &chosen {
        println!("\nprecision axis for {} ({}):", c.arch.name(), c.mode);
        println!(
            "  {:<5} {:>12} {:>7} {:>11} {:>13}",
            "Q", "R:{x,h,d}", "DSP", "FPGA [ms]", "acc@Q"
        );
        for prec in bayes_rnn_fpga::dse::precision_space() {
            let Some(reuse) = reuse_search_q(&c.arch, &ZC706, &prec) else {
                println!("  {:<5} (does not fit)", prec.name());
                continue;
            };
            let est =
                bayes_rnn_fpga::hwmodel::resource::ResourceModel::estimate_q(
                    &c.arch, &reuse, &prec,
                );
            // Latency at this format's constraint-solved reuse (timing
            // itself is format-independent at fixed reuse).
            let ms = bayes_rnn_fpga::hwmodel::LatencyModel::batch_ms(
                &c.arch,
                &reuse,
                opt.batch,
                c.s,
                ZC706.clock_hz,
            );
            let acc = lookup
                .get(&c.arch.name())
                .and_then(|e| {
                    e.metric_at("accuracy", &prec.name())
                })
                .map(|v| format!("{v:.3}"))
                .unwrap_or_else(|| "n/a".into());
            println!(
                "  {:<5} {:>12} {:>7.0} {:>11.2} {:>13}",
                prec.name(),
                format!("{{{},{},{}}}", reuse.rx, reuse.rh, reuse.rd),
                est.dsps,
                ms,
                acc
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let arch = args.get("arch").context("--arch NAME required")?;
    let cfg = parse_arch(arch)?;
    let epochs = args.usize_or("epochs", 60);
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("{arch}.weights.brt"))
    });
    let backend = args.get("backend").unwrap_or("native");

    let (train_set, _) = match cfg.task {
        Task::Anomaly => data::anomaly_splits(0),
        Task::Classify => data::splits(0),
    };
    let t0 = std::time::Instant::now();
    let params: Params = match backend {
        "native" => {
            let mut tr = NativeTrainer::new(
                cfg.clone(),
                TrainOpts {
                    epochs,
                    batch: args.usize_or("batch", 64),
                    lr: args.f32_or(
                        "lr",
                        if cfg.task == Task::Anomaly { 1e-2 } else { 5e-3 },
                    ),
                    seed: args.usize_or("seed", 0) as u64,
                },
            );
            tr.fit(&train_set);
            println!(
                "native training: {} epochs, loss {:.4} -> {:.4}",
                epochs,
                tr.loss_history[0],
                tr.final_loss()
            );
            tr.model.params
        }
        "pjrt" => {
            let mut rt = Runtime::new(&args.artifacts_dir())?;
            let batch = args.usize_or("batch", 64);
            let mut tr = PjrtTrainer::new(
                &mut rt,
                arch,
                batch,
                args.f32_or("lr", 1e-3),
                args.usize_or("seed", 0) as u64,
            )?;
            tr.fit(&train_set, epochs)?;
            println!(
                "pjrt training: {} epochs, loss {:.4} -> {:.4}",
                epochs,
                tr.loss_history.first().unwrap_or(&f32::NAN),
                tr.loss_history.last().unwrap_or(&f32::NAN)
            );
            tr.params
        }
        other => anyhow::bail!("unknown backend {other:?}"),
    };
    let named: Vec<(String, Tensor)> = cfg
        .param_names()
        .into_iter()
        .zip(params.tensors.iter().cloned())
        .collect();
    save_tensors(&out, &named)?;
    println!(
        "saved {} ({} params) in {:.1}s",
        out.display(),
        cfg.num_weights(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn load_model(args: &Args, cfg: &ArchConfig, arch: &str) -> Result<Model> {
    let path = args.get("weights").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("{arch}.weights.brt"))
    });
    let named = load_tensors(&path).with_context(|| {
        format!("{} missing — run `repro train --arch {arch}`", path.display())
    })?;
    Ok(Model::new(
        cfg.clone(),
        Params { tensors: named.into_iter().map(|(_, t)| t).collect() },
    ))
}

fn cmd_eval(args: &Args) -> Result<()> {
    let arch = args.get("arch").context("--arch NAME required")?;
    let cfg = parse_arch(arch)?;
    let model = load_model(args, &cfg, arch)?;
    let s = args.usize_or("samples", 30);
    let subset = args.usize_or("test-subset", 500);
    match cfg.task {
        Task::Anomaly => {
            let (_, test) = data::anomaly_splits(0);
            let te =
                test.subset(&(0..subset.min(test.n)).collect::<Vec<_>>());
            if args.flag("fixed") {
                let prec = args.precision()?;
                let reuse = reuse_search_q(&cfg, &ZC706, &prec)
                    .context("does not fit ZC706 at this precision")?;
                let mut acc = Accelerator::with_precision(
                    &cfg,
                    &model.params,
                    reuse,
                    7,
                    prec.clone(),
                );
                let rep = eval_anomaly(&mut acc, &te, s);
                println!(
                    "fixed-point ({})  AUC {:.3}  AP {:.3}  ACC {:.3}",
                    prec.name(),
                    rep.auc,
                    rep.ap,
                    rep.accuracy
                );
            }
            let mut p = ModelPredictor::new(&model, 7);
            let rep = eval_anomaly(&mut p, &te, s);
            println!(
                "float        AUC {:.3}  AP {:.3}  ACC {:.3}  \
                 (rmse normal {:.3} vs anomalous {:.3})",
                rep.auc,
                rep.ap,
                rep.accuracy,
                rep.mean_rmse_normal,
                rep.mean_rmse_anomalous
            );
        }
        Task::Classify => {
            let (_, test) = data::splits(0);
            let te =
                test.subset(&(0..subset.min(test.n)).collect::<Vec<_>>());
            let noise = data::gaussian_noise(50, 0);
            if args.flag("fixed") {
                let prec = args.precision()?;
                let reuse = reuse_search_q(&cfg, &ZC706, &prec)
                    .context("does not fit ZC706 at this precision")?;
                let mut acc = Accelerator::with_precision(
                    &cfg,
                    &model.params,
                    reuse,
                    7,
                    prec.clone(),
                );
                let rep = eval_classify(&mut acc, &te, &noise, s);
                println!(
                    "fixed-point ({})  ACC {:.3}  AP {:.3}  AR {:.3}  \
                     H {:.3} nats",
                    prec.name(),
                    rep.accuracy,
                    rep.ap,
                    rep.ar,
                    rep.noise_entropy
                );
            }
            let mut p = ModelPredictor::new(&model, 7);
            let rep = eval_classify(&mut p, &te, &noise, s);
            println!(
                "float        ACC {:.3}  AP {:.3}  AR {:.3}  H {:.3} nats",
                rep.accuracy, rep.ap, rep.ar, rep.noise_entropy
            );
        }
    }
    Ok(())
}

/// Build one engine factory per fleet worker — shared by `repro serve`
/// and `repro loadgen`. All engines share one design seed (MC-shard
/// determinism); `backend == "mix"` alternates fpga/gpu engines.
#[allow(clippy::too_many_arguments)]
fn engine_factories(
    cfg: &ArchConfig,
    params: &[Tensor],
    n_engines: usize,
    backend: &str,
    s: usize,
    seed: u64,
    artifacts: &std::path::Path,
    kernel_backend: KernelBackend,
    precision: &Precision,
    mask_bank: Option<std::sync::Arc<MaskBank>>,
) -> Vec<Box<dyn FnOnce() -> Engine + Send>> {
    let mut factories: Vec<Box<dyn FnOnce() -> Engine + Send>> =
        Vec::with_capacity(n_engines);
    for j in 0..n_engines {
        let kind = match backend {
            "mix" => (if j % 2 == 0 { "fpga" } else { "gpu" }).to_string(),
            other => other.to_string(),
        };
        let cfg2 = cfg.clone();
        let p2 = params.to_vec();
        let arts = artifacts.to_path_buf();
        let prec = precision.clone();
        let bank = mask_bank.clone();
        factories.push(Box::new(move || match kind.as_str() {
            "gpu" => Engine::gpu(
                Model::new(cfg2.clone(), Params { tensors: p2.clone() }),
                s,
                seed,
            ),
            "pjrt" => {
                let rt = Runtime::new(&arts).expect("artifacts");
                Engine::pjrt(rt, &cfg2.name(), &p2, s, seed)
                    .expect("pjrt engine")
            }
            _ => {
                let reuse = reuse_search_q(&cfg2, &ZC706, &prec)
                    .expect("fits ZC706 at this precision");
                let m = Model::new(
                    cfg2.clone(),
                    Params { tensors: p2.clone() },
                );
                let mut e = Engine::fpga_q(&cfg2, &m, reuse, s, seed, &prec);
                e.set_kernel_backend(kernel_backend);
                e.set_mask_bank(bank);
                e
            }
        }));
    }
    factories
}

/// `--slo-gate`: turn a failing verdict into a non-zero exit after all
/// output has been produced (CI sees the full report AND the failure).
fn check_slo_gate(gate: bool, report: Option<&SloReport>) -> Result<()> {
    if gate {
        let r = report.ok_or_else(|| {
            anyhow::anyhow!("--slo-gate needs an SLO evaluation")
        })?;
        anyhow::ensure!(
            r.pass,
            "SLO gate failed: {}",
            r.render().trim_end()
        );
    }
    Ok(())
}

/// Human-mode timeline table (capped to keep terminals readable).
fn print_timeline(tl: &Timeline) {
    const MAX_ROWS: usize = 20;
    let n = tl.windows();
    println!(
        "timeline: {n} windows x {:.0} ms",
        tl.width.as_secs_f64() * 1e3
    );
    println!(
        "  {:>4} {:>8} {:>8} {:>8} {:>8} {:>10} {:>10}",
        "w", "offered", "submit", "served", "reject", "p99_ms", "inflight"
    );
    for w in 0..n.min(MAX_ROWS) {
        let p99 = tl
            .e2e
            .window(w)
            .map(|h| h.percentile_ms(99.0))
            .unwrap_or(0.0);
        let inflight = tl
            .sample_at(w)
            .map(|s| s.max_in_flight.to_string())
            .unwrap_or_else(|| "-".to_string());
        println!(
            "  {:>4} {:>8} {:>8} {:>8} {:>8} {:>10.3} {:>10}",
            w,
            tl.offered.get(w),
            tl.submitted.get(w),
            tl.served.get(w),
            tl.rejected.get(w),
            p99,
            inflight
        );
    }
    if n > MAX_ROWS {
        println!("  ... {} more windows", n - MAX_ROWS);
    }
}

/// Top-level `"faults"` JSON fragment for serve/loadgen output lines.
/// Empty (so the line is byte-identical to fault-free releases) unless
/// chaos was configured or the fault-tolerance plane engaged.
fn fault_block_json(chaos_on: bool, f: &FaultStats) -> String {
    if !chaos_on && !f.any() {
        return String::new();
    }
    format!(
        ",\"faults\":{{\"workers_lost\":{},\
         \"shards_redispatched\":{},\"hedges_fired\":{},\
         \"hedges_won\":{},\"sessions_repinned\":{},\
         \"replies_dropped\":{}}}",
        f.workers_lost,
        f.shards_redispatched,
        f.hedges_fired,
        f.hedges_won,
        f.sessions_repinned,
        f.replies_dropped
    )
}

/// Human-readable fault-tolerance summary row.
fn print_fault_line(f: &FaultStats) {
    println!(
        "faults: workers lost {}  shards redispatched {}  hedges \
         fired {} / won {}  sessions repinned {}  replies dropped {}",
        f.workers_lost,
        f.shards_redispatched,
        f.hedges_fired,
        f.hedges_won,
        f.sessions_repinned,
        f.replies_dropped
    );
}

/// Shared `--chaos` / `--wait-timeout-ms` parsing for serve and
/// loadgen. The plan is re-seeded with the run seed so the fault
/// schedule is reproducible per run, independent of wall clock.
fn chaos_flags(
    args: &Args,
    seed: u64,
) -> Result<(Option<FaultPlan>, Option<std::time::Duration>)> {
    let chaos = match args.get("chaos") {
        Some("true") => anyhow::bail!(
            "--chaos needs a plan string, e.g. \
             kill=e1@250ms,stall=e2@100ms+50ms,drop=0.01"
        ),
        Some(p) => Some(
            FaultPlan::parse(p)
                .map_err(|e| anyhow::anyhow!(e))?
                .with_seed(seed),
        ),
        None => None,
    };
    let wait_timeout = match args.get("wait-timeout-ms") {
        Some("true") => {
            anyhow::bail!("--wait-timeout-ms needs a value in ms")
        }
        Some(v) => {
            let ms: f64 = v.parse().map_err(|_| {
                anyhow::anyhow!("--wait-timeout-ms: bad number {v:?}")
            })?;
            anyhow::ensure!(ms > 0.0, "--wait-timeout-ms must be > 0");
            Some(std::time::Duration::from_secs_f64(ms / 1e3))
        }
        None => None,
    };
    Ok((chaos, wait_timeout))
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Default arch lets the bench harness drive a bare checkout.
    let arch =
        args.get("arch").unwrap_or("classify_h8_nl1_Y").to_string();
    let cfg = parse_arch(&arch)?;
    let s =
        if cfg.is_bayesian() { args.usize_or("samples", 30) } else { 1 };
    let n_req = args.usize_or("requests", 100);
    let n_engines = args.usize_or("engines", 1).max(1);
    let router: RouterPolicy = args
        .get("router")
        .unwrap_or("rr")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    // --engine kept as a legacy alias for --backend.
    let backend = args
        .get("backend")
        .or_else(|| args.get("engine"))
        .unwrap_or("fpga")
        .to_string();
    // MC-shard merges shards numerically; mixing fixed-point FPGA and
    // float GPU samples in one reduction would break the documented
    // engine-count invariance.
    anyhow::ensure!(
        !(backend == "mix" && router == RouterPolicy::McShard),
        "--backend mix cannot be combined with --router mc-shard \
         (shards from fixed-point and float engines would be merged)"
    );
    let batch = args.usize_or("batch", 8);
    let queue_depth = args.usize_or("queue-depth", DEFAULT_QUEUE_DEPTH);
    let shed = args.flag("shed");
    let json_out = args.flag("json");
    // Observability (docs/observability.md): --obs adds stage latency
    // histograms and engine health counters to the output; --metrics /
    // --trace imply it. Off by default — serve output is then
    // byte-identical to a build without the obs layer.
    let metrics_path = match args.get("metrics") {
        Some("true") => anyhow::bail!("--metrics needs a file path"),
        p => p.map(PathBuf::from),
    };
    let trace_path = match args.get("trace") {
        Some("true") => anyhow::bail!("--trace needs a file path"),
        p => p.map(PathBuf::from),
    };
    let slo_gate = args.flag("slo-gate");
    let obs_on = args.flag("obs")
        || metrics_path.is_some()
        || trace_path.is_some()
        || args.flag("slo")
        || slo_gate;
    // With obs on, the run is additionally sliced into fixed-width
    // timeline windows (per-window histograms + gauges) and evaluated
    // against an SLO; both nest into the output next to "obs".
    let window_ms = args.f64_or("window-ms", 100.0);
    anyhow::ensure!(window_ms > 0.0, "--window-ms must be > 0");
    let obs_cfg = ObsConfig {
        enabled: obs_on,
        trace: match &trace_path {
            Some(p) => {
                Some(std::sync::Arc::new(TraceLog::create(p).with_context(
                    || format!("create trace log {}", p.display()),
                )?))
            }
            None => None,
        },
        window: obs_on.then(|| {
            std::time::Duration::from_secs_f64(window_ms / 1e3)
        }),
    };
    let slo_spec = if obs_on {
        Some(match args.get("slo") {
            None | Some("true") => SloSpec::default(),
            Some(s) => {
                SloSpec::parse(s).map_err(|e| anyhow::anyhow!(e))?
            }
        })
    } else {
        None
    };
    let seed = args.usize_or("seed", 3) as u64;
    // Deterministic fault injection (docs/serving.md §Fault
    // tolerance): same plan + seed => same fault schedule, and the
    // fault-tolerance plane keeps merged outputs bit-identical.
    let (chaos, wait_timeout) = chaos_flags(args, seed)?;
    let chaos_on = chaos.is_some();
    let artifacts = args.artifacts_dir();
    // Kernel backend selection (docs/kernels.md §Backends): --kernel
    // overrides the REPRO_KERNEL-resolved default. Every backend emits
    // bit-identical outputs — this is a cost-shape knob. `scalar`
    // additionally forces the legacy per-sample FPGA-sim loop (bench
    // baseline).
    let kernel_backend = match args.get("kernel") {
        Some(s) => {
            let b = KernelBackend::parse(s)
                .map_err(|e| anyhow::anyhow!(e))?;
            // Float engines (gpu/pjrt model forwards) dispatch through
            // the process default; keep it in sync with the flag.
            kernels::set_default_backend(b);
            b
        }
        None => kernels::default_backend(),
    };
    // Quantisation (fpga backend only): one format for every engine —
    // mc-shard merges shard numerics across engines, and the gpu/pjrt
    // float baselines have no fixed-point path.
    let precision = args.precision()?;
    anyhow::ensure!(
        precision.is_q16() || backend == "fpga",
        "--precision requires --backend fpga (float backends have no \
         quantised path)"
    );

    // Adaptive MC: sequential early-exit sampling + risk tiers
    // (docs/uncertainty.md).
    let adaptive = args.flag("adaptive-mc");
    anyhow::ensure!(
        !(adaptive && !cfg.is_bayesian()),
        "--adaptive-mc needs a Bayesian arch (pointwise nets run S = 1)"
    );
    // Adaptive rounds may land on different engines, so mixed
    // fixed-point/float backends would blend sample sets mid-request —
    // same reduction hazard as mix + mc-shard.
    anyhow::ensure!(
        !(adaptive && backend == "mix"),
        "--adaptive-mc cannot be combined with --backend mix"
    );
    let (mc_cfg, risk) = uq_flags(args, s, None)?;

    // Streaming sessions (docs/serving.md §Streaming sessions):
    // --stream C serves each request as a long-lived session whose
    // signal arrives in C chunks against resident MC lane state —
    // O(chunk) per decision instead of re-running history. --requests
    // then counts sessions; decisions land at beat boundaries.
    let stream_chunks = args.usize_or("stream", 0);
    let streaming = stream_chunks > 0;
    let stream_beats = args.usize_or("stream-beats", 4);
    let session_mb = args.usize_or("session-mb", 8);
    if streaming {
        anyhow::ensure!(
            backend == "fpga",
            "--stream requires --backend fpga (lane state lives in \
             the FPGA-sim engines)"
        );
        anyhow::ensure!(
            cfg.task == Task::Classify,
            "--stream supports the classify task only (anomaly scoring \
             is windowed, not streaming)"
        );
        anyhow::ensure!(
            stream_beats >= 1,
            "--stream-beats must be at least 1"
        );
        anyhow::ensure!(
            args.get("rate").is_none(),
            "--stream is closed-loop per chunk; use the loadgen \
             stream_monitor scenario for open-loop streaming"
        );
    }

    // Seed-indexed mask bank (docs/kernels.md §Mask bank): one bank
    // shared by every FPGA-sim engine worker, keyed by per-sample mask
    // seed, so repeat request seeds reuse bitplane rows instead of
    // re-running the LFSR samplers. 0 MiB (the default) disables it;
    // output bits are identical either way.
    let mask_bank_mb = args.usize_or("mask-bank-mb", 0);
    let mask_bank = (mask_bank_mb > 0)
        .then(|| std::sync::Arc::new(MaskBank::new(mask_bank_mb << 20)));

    // Trained weights if available; otherwise a deterministic random
    // init so load runs (and their predictions) are reproducible
    // without artifacts — the bench harness relies on this.
    let model = match load_model(args, &cfg, &arch) {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "note: {e:#}; serving untrained weights (synthetic mode)"
            );
            Model::init(cfg.clone(), &mut Rng::new(seed ^ 0xC0FFEE))
        }
    };

    // All engines share one design seed: MC-shard predictions are then
    // identical for any engine count (same request => same sample set).
    let params = model.params.tensors.clone();
    let factories = engine_factories(
        &cfg,
        &params,
        n_engines,
        &backend,
        s,
        seed,
        &artifacts,
        kernel_backend,
        &precision,
        mask_bank.clone(),
    );

    // Every backend batches: a formed batch becomes one blocked engine
    // call (FPGA-sim amortises weight fetches across the batch's MC
    // lanes), bounded by a row budget so a burst cannot form an
    // arbitrarily large blocked pass. --batch 1 streams.
    let policy = if batch <= 1 {
        BatchPolicy::stream()
    } else {
        BatchPolicy::batched_rows(
            batch,
            std::time::Duration::from_millis(2),
            batch * s.max(1),
        )
    };
    let mut fleet = Fleet::start(
        FleetConfig {
            engines: n_engines,
            router,
            policy,
            queue_depth,
            shed,
            // Adaptive streaming sessions run at the controller's
            // floor and re-serve uncertain chunks at s_max (the boost
            // tier); everything else runs the full S.
            samples: if streaming && adaptive { mc_cfg.s_min } else { s },
            obs: obs_cfg,
            session_bytes: streaming.then_some(session_mb << 20),
            session_replay: true,
            session_uq: (streaming && adaptive).then_some(mc_cfg),
            chaos,
            wait_timeout,
        },
        factories,
    );

    let (_, test) = match cfg.task {
        Task::Anomaly => data::anomaly_splits(0),
        Task::Classify => data::splits(0),
    };
    let submit_one = |fleet: &mut Fleet,
                      beat: Vec<f32>|
     -> Option<AnyTicket> {
        if adaptive {
            fleet.submit_adaptive(beat, &mc_cfg).map(AnyTicket::Adaptive)
        } else {
            fleet.submit(beat).map(AnyTicket::Fixed)
        }
    };
    // Run-start process snapshot: lets the report show CPU burned
    // *during* the run (delta), not the process-lifetime total.
    let proc0 = if obs_on { obs::proc_sample() } else { None };
    let t0 = std::time::Instant::now();
    // Checksums: the bench harness and CI compare these across engine
    // counts (MC-shard reduction) and across chunkings (streaming
    // resume contract).
    let mut pred_checksum = 0f64;
    let mut unc_checksum = 0f64;
    let mut collector = UqCollector::new();
    let mut stream_decisions = 0usize;
    let mut stream_boosted = 0usize;
    if streaming {
        // Each of the n_req sessions monitors a signal of
        // --stream-beats consecutive test beats, arriving in --stream
        // equal chunks. Chunk rounds are interleaved across sessions
        // (submit all, wait all) so affinity placement is exercised
        // while each session's chunks stay ordered.
        let idim = cfg.input_dim.max(1);
        let mut sids = Vec::with_capacity(n_req);
        let mut signals: Vec<Vec<f32>> = Vec::with_capacity(n_req);
        for j in 0..n_req {
            let mut sig = Vec::new();
            for b in 0..stream_beats {
                sig.extend_from_slice(
                    test.beat((j * stream_beats + b) % test.n),
                );
            }
            signals.push(sig);
            sids.push(
                fleet
                    .open_session()
                    .map_err(|e| anyhow::anyhow!("{e}"))?,
            );
        }
        // Per-session decision accumulators, folded in canonical
        // (session, beat) order afterwards so the checksum is
        // invariant to how chunk rounds interleave.
        let mut sums: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n_req];
        for c in 0..stream_chunks {
            let mut round = Vec::with_capacity(n_req);
            for (j, sid) in sids.iter().enumerate() {
                let steps = signals[j].len() / idim;
                let lo = steps * c / stream_chunks * idim;
                let hi = steps * (c + 1) / stream_chunks * idim;
                round.push((
                    j,
                    fleet
                        .submit_chunk(*sid, signals[j][lo..hi].to_vec())
                        .map_err(|e| anyhow::anyhow!("{e}"))?,
                ));
            }
            for (j, t) in round {
                let resp =
                    fleet.wait_chunk(t).map_err(anyhow::Error::msg)?;
                if resp.boosted {
                    stream_boosted += 1;
                }
                for b in &resp.beats {
                    let (mean, std) = b.mean_std();
                    sums[j].push((
                        mean.iter().map(|&v| v as f64).sum(),
                        std.iter().map(|&v| v as f64).sum(),
                    ));
                }
            }
        }
        for sid in sids {
            fleet
                .close_session(sid)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        for per_session in &sums {
            for &(p, u) in per_session {
                pred_checksum += p;
                unc_checksum += u;
                stream_decisions += 1;
            }
        }
    } else {
        let mut tickets = Vec::with_capacity(n_req);
        if let Some(rate) =
            args.get("rate").and_then(|v| v.parse::<f64>().ok())
        {
            // Open-loop Poisson arrivals: exposes the latency knee and,
            // with --shed, the admission-control behaviour under
            // overload.
            let trace = PoissonTrace::generate(rate, n_req, &test, seed);
            let start = std::time::Instant::now();
            for a in &trace.arrivals {
                if let Some(wait) = a.at.checked_sub(start.elapsed()) {
                    if !wait.is_zero() {
                        std::thread::sleep(wait);
                    }
                }
                if let Some(t) =
                    submit_one(&mut fleet, test.beat(a.beat_idx).to_vec())
                {
                    tickets.push(t);
                }
            }
        } else {
            // Closed loop: submit everything, then wait.
            for i in 0..n_req {
                if let Some(t) =
                    submit_one(&mut fleet, test.beat(i % test.n).to_vec())
                {
                    tickets.push(t);
                }
            }
        }

        // Checksums over the first 8 responses (submit order): the
        // bench harness compares these across engine counts to verify
        // the MC-shard reduction numerically.
        for (i, t) in tickets.into_iter().enumerate() {
            let (mean, std) = match t {
                AnyTicket::Fixed(t) => {
                    let resp = fleet.wait(t)?;
                    (resp.prediction.mean, resp.prediction.std)
                }
                AnyTicket::Adaptive(t) => {
                    let resp = fleet.wait_adaptive(t)?;
                    // Risk-tier the request on its raw MC evidence.
                    let tier = match cfg.task {
                        Task::Classify => {
                            let probs: Vec<f64> = resp
                                .samples
                                .iter()
                                .map(|&v| v as f64)
                                .collect();
                            risk.classify(
                                &probs,
                                resp.s_used,
                                resp.out_len,
                                resp.converged,
                            )
                            .tier
                        }
                        Task::Anomaly => risk.grade_regression(
                            &resp.prediction.std,
                            resp.converged,
                        ),
                    };
                    collector.record(resp.s_used, resp.converged, tier);
                    collector.record_rounds(resp.rounds);
                    (resp.prediction.mean, resp.prediction.std)
                }
            };
            if i < 8 {
                pred_checksum +=
                    mean.iter().map(|&v| v as f64).sum::<f64>();
                unc_checksum +=
                    std.iter().map(|&v| v as f64).sum::<f64>();
            }
        }
    }
    let uq_report =
        (adaptive && !streaming).then(|| collector.finish(s));
    let wall = t0.elapsed();
    let mut summary = fleet.join();
    // Stamp bank counters before any export path reads the summary;
    // stays `None` when disabled so the output is byte-identical.
    summary.obs.mask_bank = mask_bank.as_ref().map(|b| b.stats());
    let throughput = if wall.as_secs_f64() > 0.0 {
        summary.served as f64 / wall.as_secs_f64()
    } else {
        0.0
    };
    // SLO verdict: exact overall attainment from the sample-keeping
    // stats, per-window burn rates from the timeline histograms.
    let slo_report = slo_spec.map(|spec| {
        let over = summary.e2e.count_over_ms(spec.latency_ms);
        obs::slo::evaluate(
            &spec,
            summary.served,
            summary.rejected,
            over,
            summary.timeline.as_ref(),
        )
    });
    // Exported metrics (JSON + Prometheus text exposition) ride on the
    // obs histograms; written in both output modes.
    if let Some(path) = &metrics_path {
        let mut set =
            serve_metric_set(&summary, wall.as_secs_f64(), throughput);
        if let Some(tl) = &summary.timeline {
            push_timeline_metrics(&mut set, tl);
        }
        if let Some(r) = &slo_report {
            push_slo_metrics(&mut set, r);
        }
        std::fs::write(path, jsonio::write(&set.to_json()) + "\n")
            .with_context(|| format!("write {}", path.display()))?;
        let prom = PathBuf::from(format!("{}.prom", path.display()));
        std::fs::write(&prom, set.to_prometheus())
            .with_context(|| format!("write {}", prom.display()))?;
    }
    // Built before any `&mut` percentile call below; empty when obs is
    // off so the JSON line stays byte-identical to the pre-obs format.
    let obs_json = if obs_on {
        format!(
            ",\"obs\":{}",
            jsonio::write(&serve_obs_json(&summary, proc0))
        )
    } else {
        String::new()
    };
    let timeline_json = summary
        .timeline
        .as_ref()
        .map(|tl| {
            format!(",\"timeline\":{}", jsonio::write(&tl.to_json()))
        })
        .unwrap_or_default();
    let slo_json = slo_report
        .as_ref()
        .map(|r| format!(",\"slo\":{}", jsonio::write(&r.to_json())))
        .unwrap_or_default();
    let mut engine_stats = summary.engine_stats();

    // Streaming block: per-run session/decision counts for the bench
    // harness and the CI chunked-equals-oneshot check. Absent (and the
    // line byte-identical to non-streaming runs) without --stream.
    let stream_json = if streaming {
        let ss = summary.obs.sessions.unwrap_or_default();
        format!(
            ",\"stream\":{{\"sessions\":{n_req},\
             \"chunks_per_session\":{stream_chunks},\
             \"beats_per_session\":{stream_beats},\
             \"decisions\":{stream_decisions},\
             \"boosted_chunks\":{stream_boosted},\
             \"evictions\":{},\"replay_rebuilds\":{}}}",
            ss.evictions, ss.replay_rebuilds
        )
    } else {
        String::new()
    };

    // Fault block: present only when chaos was configured or the
    // fault-tolerance plane actually engaged, so a fault-free run's
    // output line stays byte-identical to earlier releases.
    let faults_json = fault_block_json(chaos_on, &summary.obs.faults);

    if json_out {
        // Single-line JSON for the process-based bench harness. The
        // adaptive report rides along as one nested object.
        let adaptive_json = uq_report
            .as_ref()
            .map(|r| format!(",\"adaptive\":{}", r.to_json_line()))
            .unwrap_or_default();
        println!(
            "{{\"cmd\":\"serve\",\"arch\":\"{arch}\",\"engines\":{n_engines},\
             \"router\":\"{}\",\"backend\":\"{backend}\",\
             \"kernel\":\"{}\",\"precision\":\"{}\",\"samples\":{s},\
             \"requests\":{n_req},\"served\":{},\"rejected\":{},\
             \"wall_s\":{:.6},\"throughput_rps\":{:.3},\
             \"e2e_ms\":{{\"mean\":{:.4},\"p50\":{:.4},\"p99\":{:.4},\
             \"max\":{:.4}}},\
             \"engine_ms\":{{\"mean\":{:.4},\"p99\":{:.4}}},\
             \"batches\":{},\"pred_checksum\":{:.6},\
             \"unc_checksum\":{:.6}{}{}{}{}{}{}}}",
            router.as_str(),
            kernel_backend.name(),
            precision.name(),
            summary.served,
            summary.rejected,
            wall.as_secs_f64(),
            throughput,
            summary.e2e.mean_ms(),
            summary.e2e.percentile_ms(50.0),
            summary.e2e.percentile_ms(99.0),
            summary.e2e.max_ms(),
            engine_stats.mean_ms(),
            engine_stats.percentile_ms(99.0),
            summary.batches(),
            pred_checksum,
            unc_checksum,
            stream_json,
            adaptive_json,
            faults_json,
            obs_json,
            timeline_json,
            slo_json,
        );
        return check_slo_gate(slo_gate, slo_report.as_ref());
    }

    println!(
        "fleet: {n_engines} x {backend} engines, router {}, S={s}, \
         kernel {}, precision {}{}",
        router.as_str(),
        kernel_backend.name(),
        precision.name(),
        if shed { ", shedding on" } else { "" }
    );
    println!(
        "served {} / {} requests in {:.2}s  ({throughput:.1} req/s)  \
         rejected {}",
        summary.served,
        n_req,
        wall.as_secs_f64(),
        summary.rejected
    );
    println!(
        "e2e    mean {:.3} ms  p50 {:.3}  p99 {:.3}  max {:.3}",
        summary.e2e.mean_ms(),
        summary.e2e.percentile_ms(50.0),
        summary.e2e.percentile_ms(99.0),
        summary.e2e.max_ms()
    );
    println!(
        "engine mean {:.3} ms  batches {} (avg size {:.2})",
        engine_stats.mean_ms(),
        summary.batches(),
        if summary.batches() > 0 {
            summary.items() as f64 / summary.batches() as f64
        } else {
            0.0
        }
    );
    for (j, e) in summary.per_engine.iter().enumerate() {
        println!(
            "  engine[{j}]  items {:<6} batches {:<6} model mean {:.3} ms",
            e.served, e.batches, e.engine.mean_ms()
        );
    }
    if let Some(b) = &summary.obs.mask_bank {
        println!(
            "mask bank: {mask_bank_mb} MiB budget  hits {}  misses {}  \
             evictions {}  resident {:.1} KiB",
            b.hits,
            b.misses,
            b.evictions,
            b.resident_bytes as f64 / 1024.0
        );
    }
    if let Some(ss) = &summary.obs.sessions {
        println!(
            "sessions: {n_req} x {stream_chunks} chunks \
             ({stream_beats} beats each)  decisions {stream_decisions}  \
             boosted {stream_boosted}  evictions {}  replay rebuilds {}  \
             budget {session_mb} MiB",
            ss.evictions, ss.replay_rebuilds
        );
    }
    if chaos_on || summary.obs.faults.any() {
        print_fault_line(&summary.obs.faults);
    }
    if obs_on {
        let stages = summary.stage_stats();
        let row = |name: &str, h: &LogHistogram| {
            println!(
                "  stage {name:<8} n {:<6} p50 {:>8.3} ms  p99 {:>8.3}  \
                 max {:>8.3}",
                h.count(),
                h.percentile_ms(50.0),
                h.percentile_ms(99.0),
                h.max_ms()
            );
        };
        println!("stages (queue -> batch-form -> compute -> merge):");
        row("queue", &stages.queue);
        row("batch", &stages.batch);
        row("compute", &stages.compute);
        row("merge", &summary.obs.merge);
        row("e2e", &summary.obs.e2e);
        println!(
            "mc samples: spent {}  saved {}   router placements {:?}",
            summary.obs.mc_spent,
            summary.obs.mc_saved,
            summary.obs.placements
        );
        for (j, e) in summary.per_engine.iter().enumerate() {
            println!(
                "  engine[{j}]  kernel {:<13} peak batch {:<4} \
                 queue highwater {:<4} sheds {}",
                e.kernel, e.peak_batch, e.queue_highwater, e.sheds
            );
        }
        if let Some(p) = obs::proc_sample() {
            match proc0 {
                Some(p0) => println!(
                    "process: rss {:.1} MiB  cpu {:.2} s \
                     (this run {:.2} s)",
                    p.rss_bytes as f64 / (1024.0 * 1024.0),
                    p.cpu_seconds,
                    p.cpu_delta_since(&p0)
                ),
                None => println!(
                    "process: rss {:.1} MiB  cpu {:.2} s",
                    p.rss_bytes as f64 / (1024.0 * 1024.0),
                    p.cpu_seconds
                ),
            }
        }
        if let Some(tl) = &summary.timeline {
            print_timeline(tl);
        }
        if let Some(r) = &slo_report {
            print!("{}", r.render());
        }
        if let Some(path) = &metrics_path {
            println!(
                "metrics written to {} (+ {}.prom)",
                path.display(),
                path.display()
            );
        }
        if let Some(path) = &trace_path {
            println!("trace events written to {}", path.display());
        }
    }
    if let Some(r) = &uq_report {
        println!("{}", r.render());
    }
    check_slo_gate(slo_gate, slo_report.as_ref())
}

/// `repro loadgen` — the open-loop scenario runner. Unlike `serve
/// --rate` (closed-loop submit helpers retrofitted with sleeps), this
/// path is coordinated-omission-correct: every request's e2e clock
/// starts at its *scheduled* Poisson arrival, offered load is recorded
/// per timeline window against the fleet's epoch, and the run is
/// always evaluated against an SLO. Observability is always on here —
/// the whole point of the command is the timeline.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let scenario = match args.get("scenario") {
        None | Some("true") => "baseline".to_string(),
        Some(s) => s.to_string(),
    };
    let arch =
        args.get("arch").unwrap_or("classify_h8_nl1_Y").to_string();
    let cfg = parse_arch(&arch)?;
    let s =
        if cfg.is_bayesian() { args.usize_or("samples", 8) } else { 1 };
    let n_req = args.usize_or("requests", 64);
    let rate = args.f64_or("rate", 200.0);
    anyhow::ensure!(rate > 0.0, "--rate must be > 0");
    let n_engines = args.usize_or("engines", 4).max(1);
    let seed = args.usize_or("seed", 3) as u64;
    // Deterministic fault injection, as in `serve` (docs/serving.md
    // §Fault tolerance). Degraded requests are counted, not fatal —
    // the loadgen report conserves offered = served + shed + degraded.
    let (chaos, wait_timeout) = chaos_flags(args, seed)?;
    let chaos_on = chaos.is_some();
    let backend = args
        .get("backend")
        .or_else(|| args.get("engine"))
        .unwrap_or("fpga")
        .to_string();
    anyhow::ensure!(
        backend != "mix",
        "loadgen scenarios route per request; use serve for --backend mix"
    );
    let mut spec = ScenarioSpec::preset(
        &scenario, n_engines, rate, n_req, s, seed,
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    // CLI overrides on top of the preset's topology.
    if let Some(d) = args.get("queue-depth") {
        spec.queue_depth = d
            .parse()
            .map_err(|_| anyhow::anyhow!("--queue-depth wants a number"))?;
    }
    if args.flag("shed") {
        spec.shed = true;
    }
    // stream_monitor replays the trace as long-lived session chunks
    // instead of independent requests (docs/serving.md §Streaming
    // sessions); the other scenarios are untouched by these knobs.
    let stream_mode = spec.name == "stream_monitor";
    let n_sessions = args.usize_or("sessions", spec.engines * 4).max(1);
    let session_mb = args.usize_or("session-mb", 8);
    anyhow::ensure!(
        stream_mode || args.get("sessions").is_none(),
        "--sessions only applies to --scenario stream_monitor"
    );
    anyhow::ensure!(
        !stream_mode || backend == "fpga",
        "stream_monitor needs --backend fpga (resident lane state is \
         an FPGA-path feature)"
    );
    anyhow::ensure!(
        !stream_mode || cfg.task == Task::Classify,
        "stream_monitor supports the classify task only (anomaly \
         scoring is windowed, not streaming)"
    );
    let batch = args.usize_or("batch", 8);
    let json_out = args.flag("json");
    let metrics_path = match args.get("metrics") {
        Some("true") => anyhow::bail!("--metrics needs a file path"),
        p => p.map(PathBuf::from),
    };
    let trace_path = match args.get("trace") {
        Some("true") => anyhow::bail!("--trace needs a file path"),
        p => p.map(PathBuf::from),
    };
    let slo_gate = args.flag("slo-gate");
    let window_ms = args.f64_or("window-ms", 100.0);
    anyhow::ensure!(window_ms > 0.0, "--window-ms must be > 0");
    let slo_spec = match args.get("slo") {
        None | Some("true") => SloSpec::default(),
        Some(s) => SloSpec::parse(s).map_err(|e| anyhow::anyhow!(e))?,
    };
    let obs_cfg = ObsConfig {
        enabled: true,
        trace: match &trace_path {
            Some(p) => {
                Some(std::sync::Arc::new(TraceLog::create(p).with_context(
                    || format!("create trace log {}", p.display()),
                )?))
            }
            None => None,
        },
        window: Some(std::time::Duration::from_secs_f64(
            window_ms / 1e3,
        )),
    };
    let kernel_backend = match args.get("kernel") {
        Some(k) => {
            let b = KernelBackend::parse(k)
                .map_err(|e| anyhow::anyhow!(e))?;
            kernels::set_default_backend(b);
            b
        }
        None => kernels::default_backend(),
    };
    let precision = args.precision()?;
    anyhow::ensure!(
        precision.is_q16() || backend == "fpga",
        "--precision requires --backend fpga (float backends have no \
         quantised path)"
    );
    let model = match load_model(args, &cfg, &arch) {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "note: {e:#}; serving untrained weights (synthetic mode)"
            );
            Model::init(cfg.clone(), &mut Rng::new(seed ^ 0xC0FFEE))
        }
    };
    // Shared mask bank, as in `serve` (0 = off, the default).
    let mask_bank_mb = args.usize_or("mask-bank-mb", 0);
    let mask_bank = (mask_bank_mb > 0)
        .then(|| std::sync::Arc::new(MaskBank::new(mask_bank_mb << 20)));
    let params = model.params.tensors.clone();
    // Engines are sized for the heaviest payload class (a poisson_mix
    // "heavy" request draws 2S samples).
    let engine_s = spec
        .mix
        .iter()
        .map(|c| c.samples)
        .max()
        .unwrap_or(spec.samples)
        .max(spec.samples);
    let factories = engine_factories(
        &cfg,
        &params,
        spec.engines,
        &backend,
        engine_s,
        seed,
        &args.artifacts_dir(),
        kernel_backend,
        &precision,
        mask_bank.clone(),
    );
    let policy = if batch <= 1 {
        BatchPolicy::stream()
    } else {
        BatchPolicy::batched_rows(
            batch,
            std::time::Duration::from_millis(2),
            batch * engine_s.max(1),
        )
    };
    let proc0 = obs::proc_sample();
    let mut fleet = Fleet::start(
        FleetConfig {
            engines: spec.engines,
            router: spec.router,
            policy,
            queue_depth: spec.queue_depth,
            shed: spec.shed,
            samples: spec.samples,
            obs: obs_cfg,
            session_bytes: stream_mode.then_some(session_mb << 20),
            chaos,
            wait_timeout,
            ..FleetConfig::default()
        },
        factories,
    );
    let (_, test) = match cfg.task {
        Task::Anomaly => data::anomaly_splits(0),
        Task::Classify => data::splits(0),
    };
    let sched = spec.trace(test.n);
    let t0 = std::time::Instant::now();
    let (outcome, stream_work) = if stream_mode {
        let run =
            run_stream_open_loop(&mut fleet, &sched, &test, n_sessions)
                .map_err(|e| anyhow::anyhow!(e))?;
        let outcome = OpenLoopOutcome {
            offered: run.offered,
            submitted: run.tickets.len(),
            lag: run.lag,
            offered_per_window: run.offered_per_window,
            ..OpenLoopOutcome::default()
        };
        (outcome, Some((run.tickets, run.sids)))
    } else {
        (run_open_loop(&mut fleet, &sched, &test), None)
    };
    let mut e2e = bayes_rnn_fpga::coordinator::LatencyStats::new();
    // Per-class served counts, offered alongside for the mix report.
    let n_classes = spec.mix.len().max(1);
    let mut served_by_class = vec![0usize; n_classes];
    // Requests that timed out degraded (lost replies under --chaos
    // drop plans) are counted, not fatal: the conservation report
    // still accounts for every offered request. Hard engine errors
    // stay fatal.
    let mut degraded = 0usize;
    if let Some((tickets, sids)) = stream_work {
        for t in tickets {
            match fleet.wait_chunk(t) {
                Ok(resp) => {
                    e2e.record_ms(resp.e2e_ms);
                    served_by_class[0] += 1;
                }
                Err(e @ FleetError::Degraded { .. }) => {
                    degraded += 1;
                    eprintln!("note: {e}");
                }
                Err(e) => return Err(anyhow::anyhow!("{e}")),
            }
        }
        for sid in sids {
            fleet
                .close_session(sid)
                .map_err(|e| anyhow::anyhow!(e))?;
        }
    } else {
        for (ticket, class) in outcome.tickets {
            match fleet.wait(ticket) {
                Ok(resp) => {
                    e2e.record_ms(resp.e2e_ms);
                    served_by_class[class] += 1;
                }
                Err(e @ FleetError::Degraded { .. }) => {
                    degraded += 1;
                    eprintln!("note: {e}");
                }
                Err(e) => return Err(anyhow::anyhow!("{e}")),
            }
        }
    }
    let wall = t0.elapsed();
    let mut summary = fleet.join();
    summary.obs.mask_bank = mask_bank.as_ref().map(|b| b.stats());
    // The fleet only sees submissions; the schedule knows what was
    // *offered* (including requests shed at admission) — graft the
    // offered-per-window series onto the timeline for the
    // offered-vs-achieved comparison.
    if let Some(tl) = summary.timeline.as_mut() {
        tl.offered = outcome.offered_per_window.clone();
    }
    let achieved_rps = if wall.as_secs_f64() > 0.0 {
        summary.served as f64 / wall.as_secs_f64()
    } else {
        0.0
    };
    let slo_report = {
        let over = summary.e2e.count_over_ms(slo_spec.latency_ms);
        obs::slo::evaluate(
            &slo_spec,
            summary.served,
            summary.rejected,
            over,
            summary.timeline.as_ref(),
        )
    };
    if let Some(path) = &metrics_path {
        let mut set =
            serve_metric_set(&summary, wall.as_secs_f64(), achieved_rps);
        if let Some(tl) = &summary.timeline {
            push_timeline_metrics(&mut set, tl);
        }
        push_slo_metrics(&mut set, &slo_report);
        std::fs::write(path, jsonio::write(&set.to_json()) + "\n")
            .with_context(|| format!("write {}", path.display()))?;
        let prom = PathBuf::from(format!("{}.prom", path.display()));
        std::fs::write(&prom, set.to_prometheus())
            .with_context(|| format!("write {}", prom.display()))?;
    }
    let mut lag = outcome.lag;
    let mix_json: Vec<String> = spec
        .mix
        .iter()
        .enumerate()
        .map(|(i, c)| {
            format!(
                "{{\"class\":\"{}\",\"samples\":{},\"weight\":{},\
                 \"served\":{}}}",
                c.name, c.samples, c.weight, served_by_class[i]
            )
        })
        .collect();
    // Streaming-session block; empty for the non-stream scenarios so
    // their JSON line stays byte-identical.
    let stream_json = summary
        .obs
        .sessions
        .map(|ss| {
            format!(
                ",\"stream\":{{\"sessions\":{},\"chunks\":{},\
                 \"boosted_chunks\":{},\"evictions\":{},\
                 \"replay_rebuilds\":{},\"resident_bytes\":{}}}",
                ss.opened,
                ss.chunks,
                ss.boosted_chunks,
                ss.evictions,
                ss.replay_rebuilds,
                ss.resident_bytes
            )
        })
        .unwrap_or_default();
    // Fault-tolerance block (plus the degraded-request count), present
    // only under --chaos or when the plane engaged — fault-free lines
    // stay byte-identical to earlier releases.
    let faults_json = {
        let mut f = fault_block_json(chaos_on, &summary.obs.faults);
        if chaos_on || degraded > 0 {
            f.push_str(&format!(",\"degraded\":{degraded}"));
        }
        f
    };
    if json_out {
        let obs_json = format!(
            ",\"obs\":{}",
            jsonio::write(&serve_obs_json(&summary, proc0))
        );
        let timeline_json = summary
            .timeline
            .as_ref()
            .map(|tl| {
                format!(",\"timeline\":{}", jsonio::write(&tl.to_json()))
            })
            .unwrap_or_default();
        println!(
            "{{\"cmd\":\"loadgen\",\"scenario\":\"{scenario}\",\
             \"arch\":\"{arch}\",\"engines\":{},\"router\":\"{}\",\
             \"backend\":\"{backend}\",\"rate_per_s\":{rate},\
             \"requests\":{n_req},\"offered\":{},\"submitted\":{},\
             \"served\":{},\"rejected\":{},\"wall_s\":{:.6},\
             \"achieved_rps\":{:.3},\
             \"lag_ms\":{{\"p50\":{:.4},\"p99\":{:.4}}},\
             \"e2e_ms\":{{\"mean\":{:.4},\"p50\":{:.4},\"p99\":{:.4},\
             \"max\":{:.4}}},\"mix\":[{}]{}{}{}{},\"slo\":{}}}",
            spec.engines,
            spec.router.as_str(),
            outcome.offered,
            outcome.submitted,
            summary.served,
            summary.rejected,
            wall.as_secs_f64(),
            achieved_rps,
            lag.percentile_ms(50.0),
            lag.percentile_ms(99.0),
            e2e.mean_ms(),
            e2e.percentile_ms(50.0),
            e2e.percentile_ms(99.0),
            e2e.max_ms(),
            mix_json.join(","),
            stream_json,
            faults_json,
            obs_json,
            timeline_json,
            jsonio::write(&slo_report.to_json()),
        );
        return check_slo_gate(slo_gate, Some(&slo_report));
    }
    println!(
        "loadgen {scenario}: {} x {backend} engines, router {}, \
         rate {rate:.0} req/s, S={}",
        spec.engines,
        spec.router.as_str(),
        spec.samples
    );
    println!(
        "offered {} (submitted {}, shed-at-submit {})  served {}  \
         in {:.2}s  ({achieved_rps:.1} req/s achieved)",
        outcome.offered,
        outcome.submitted,
        outcome.rejected_at_submit,
        summary.served,
        wall.as_secs_f64()
    );
    println!(
        "generator lag p50 {:.3} ms  p99 {:.3} ms (how late submits \
         ran vs schedule)",
        lag.percentile_ms(50.0),
        lag.percentile_ms(99.0)
    );
    println!(
        "e2e (from scheduled arrival)  mean {:.3} ms  p50 {:.3}  \
         p99 {:.3}  max {:.3}",
        e2e.mean_ms(),
        e2e.percentile_ms(50.0),
        e2e.percentile_ms(99.0),
        e2e.max_ms()
    );
    if !spec.mix.is_empty() {
        for (i, c) in spec.mix.iter().enumerate() {
            println!(
                "  class {:<9} S={:<3} weight {:.2}  served {}",
                c.name, c.samples, c.weight, served_by_class[i]
            );
        }
    }
    if let Some(b) = &summary.obs.mask_bank {
        println!(
            "mask bank: {mask_bank_mb} MiB budget  hits {}  misses {}  \
             evictions {}  resident {:.1} KiB",
            b.hits,
            b.misses,
            b.evictions,
            b.resident_bytes as f64 / 1024.0
        );
    }
    if let Some(ss) = &summary.obs.sessions {
        println!(
            "sessions: {} open-loop streams, {} chunks  boosted {}  \
             evictions {}  replay rebuilds {}  budget {session_mb} MiB",
            ss.opened,
            ss.chunks,
            ss.boosted_chunks,
            ss.evictions,
            ss.replay_rebuilds
        );
    }
    if chaos_on || summary.obs.faults.any() || degraded > 0 {
        print_fault_line(&summary.obs.faults);
        println!("degraded (reply lost past timeout): {degraded}");
    }
    if let Some(tl) = &summary.timeline {
        print_timeline(tl);
    }
    print!("{}", slo_report.render());
    if let Some(path) = &metrics_path {
        println!(
            "metrics written to {} (+ {}.prom)",
            path.display(),
            path.display()
        );
    }
    if let Some(path) = &trace_path {
        println!("trace events written to {}", path.display());
    }
    check_slo_gate(slo_gate, Some(&slo_report))
}

fn cmd_uq(args: &Args) -> Result<()> {
    match args.positional(1).unwrap_or("evaluate") {
        "calibrate" => cmd_uq_calibrate(args),
        "evaluate" => cmd_uq_evaluate(args),
        "report" => cmd_uq_report(args),
        other => {
            print_usage();
            anyhow::bail!(
                "unknown uq action {other:?} (calibrate | evaluate | report)"
            )
        }
    }
}

/// Shared `repro uq` setup: arch + accelerator + test subset. Falls back
/// to a deterministic random init when trained weights are missing, like
/// `repro serve` (synthetic mode — relative numbers still exercise the
/// whole pipeline). `offset` slices disjoint windows of the test split:
/// `calibrate` fits on beats `0..subset`, `evaluate` scores the *next*
/// `subset` beats so its NLL/ECE/accuracy are held-out, not in-sample.
struct UqSetup {
    arch: String,
    k: usize,
    s: usize,
    /// First beat index of the subset window (also salts request seeds
    /// so calibrate and evaluate never share an MC sample set).
    offset: usize,
    acc: Accelerator,
    test: data::Dataset,
}

/// Compute the `[start, end)` window of the test split for
/// `uq calibrate` (window 0) / `uq evaluate` (window 1). The windows
/// must be disjoint — evaluate's metrics are held-out — so a `--subset`
/// large enough to push a later window past the end of the split is a
/// hard error rather than a silent clamp onto the calibration window
/// (ROADMAP PR 3 review finding b).
fn uq_window(
    test_n: usize,
    subset: usize,
    offset_windows: usize,
) -> Result<(usize, usize)> {
    let start = offset_windows * subset;
    anyhow::ensure!(
        start < test_n,
        "--subset {subset} puts window {offset_windows} at beats \
         {start}.. but the test split has only {test_n} beats; \
         `uq evaluate` must score beats disjoint from the \
         `uq calibrate` window — use --subset <= {}",
        test_n / (offset_windows.max(1) + 1)
    );
    Ok((start, (start + subset).min(test_n)))
}

fn uq_setup(args: &Args, offset_windows: usize) -> Result<UqSetup> {
    let arch =
        args.get("arch").unwrap_or("classify_h8_nl1_Y").to_string();
    let cfg = parse_arch(&arch)?;
    anyhow::ensure!(
        cfg.task == Task::Classify,
        "repro uq needs the classify task (probabilistic head); \
         the anomaly task is tiered inline by `repro serve --adaptive-mc`"
    );
    anyhow::ensure!(
        cfg.is_bayesian(),
        "repro uq needs a Bayesian arch (MC dropout off ⇒ no uncertainty)"
    );
    let seed = args.usize_or("seed", 7) as u64;
    let model = match load_model(args, &cfg, &arch) {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "note: {e:#}; using deterministic random init \
                 (synthetic mode)"
            );
            Model::init(cfg.clone(), &mut Rng::new(seed ^ 0xC0FFEE))
        }
    };
    let reuse =
        reuse_search(&cfg, &ZC706).context("does not fit ZC706")?;
    let acc = Accelerator::new(&cfg, &model.params, reuse, seed);
    let (_, test) = data::splits(0);
    let subset = args.usize_or("subset", 200).max(1);
    let (offset, end) = uq_window(test.n, subset, offset_windows)?;
    let test = test.subset(&(offset..end).collect::<Vec<_>>());
    anyhow::ensure!(test.n > 0, "empty test window ({offset}..{end})");
    let s = args.usize_or("samples", 30);
    anyhow::ensure!(s >= 1, "--samples must be >= 1");
    Ok(UqSetup { arch, k: cfg.num_classes, s, offset, acc, test })
}

fn default_calibration_path(args: &Args, arch: &str) -> PathBuf {
    args.artifacts_dir().join(format!("uq_calibration_{arch}.json"))
}

/// `repro uq calibrate`: fixed-S MC predictions on the held-out subset,
/// temperature fitted by NLL, saved for `uq evaluate` / `serve
/// --calibration`.
fn cmd_uq_calibrate(args: &Args) -> Result<()> {
    let mut su = uq_setup(args, 0)?;
    let k = su.k;
    let mut probs = Vec::with_capacity(su.test.n * k);
    for i in 0..su.test.n {
        let out = su.acc.predict_seeded(
            su.test.beat(i),
            (su.offset + i) as u64,
            0,
            su.s,
        );
        probs.extend(out.mean().iter().map(|&v| v as f64));
    }
    let labels = &su.test.y;
    let scaler = TemperatureScaler::fit(&probs, labels, k);
    let id = TemperatureScaler::identity();
    let nll_raw = id.nll(&probs, labels, k);
    let nll_cal = scaler.nll(&probs, labels, k);
    let ece_raw = id.ece(&probs, labels, k);
    let ece_cal = scaler.ece(&probs, labels, k);
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| default_calibration_path(args, &su.arch));
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, format!("{}\n", scaler.to_json()))
        .with_context(|| format!("writing {}", out.display()))?;
    if args.flag("json") {
        println!(
            "{{\"cmd\":\"uq_calibrate\",\"arch\":\"{}\",\"samples\":{},\
             \"subset\":{},\"temperature\":{:.4},\"nll_raw\":{:.4},\
             \"nll_calibrated\":{:.4},\"ece_raw\":{:.4},\
             \"ece_calibrated\":{:.4},\"out\":\"{}\"}}",
            su.arch,
            su.s,
            su.test.n,
            scaler.temperature,
            nll_raw,
            nll_cal,
            ece_raw,
            ece_cal,
            out.display()
        );
    } else {
        println!(
            "fitted temperature T = {:.3} on {} beats (S = {})",
            scaler.temperature, su.test.n, su.s
        );
        println!("NLL  {nll_raw:.4} -> {nll_cal:.4}");
        println!("ECE  {ece_raw:.4} -> {ece_cal:.4}");
        println!("saved {}", out.display());
    }
    Ok(())
}

/// `repro uq evaluate`: run the adaptive controller + risk tiers over
/// the test subset and a Gaussian-noise OOD probe, write the report.
fn cmd_uq_evaluate(args: &Args) -> Result<()> {
    // Window 1: disjoint from the window `uq calibrate` fitted on, so
    // every calibrated metric below is held-out.
    let mut su = uq_setup(args, 1)?;
    let k = su.k;
    let (mc, risk) = uq_flags(
        args,
        su.s,
        Some(default_calibration_path(args, &su.arch)),
    )?;

    let mut collector = UqCollector::new();
    let (mut correct_all, mut correct_accept, mut accept_n) = (0, 0, 0);
    for i in 0..su.test.n {
        let out = su.acc.predict_adaptive(
            su.test.beat(i),
            (su.offset + i) as u64,
            &mc,
        );
        let probs: Vec<f64> =
            out.samples.iter().map(|&v| v as f64).collect();
        let d = risk.classify(&probs, out.s_used, k, out.converged);
        collector.record(out.s_used, out.converged, d.tier);
        let ok = bayes_rnn_fpga::metrics::argmax(&d.calibrated)
            == su.test.label(i) as usize;
        if ok {
            correct_all += 1;
        }
        if d.tier == RiskTier::Accept {
            accept_n += 1;
            if ok {
                correct_accept += 1;
            }
        }
    }
    // OOD probe: Gaussian noise should land in the abstain tier.
    let noise = data::gaussian_noise(32, 1);
    let mut noise_abstain = 0usize;
    for i in 0..noise.n {
        let out = su.acc.predict_adaptive(
            noise.beat(i),
            (su.offset + su.test.n + i) as u64,
            &mc,
        );
        let probs: Vec<f64> =
            out.samples.iter().map(|&v| v as f64).collect();
        let d = risk.classify(&probs, out.s_used, k, out.converged);
        if d.tier == RiskTier::Abstain {
            noise_abstain += 1;
        }
    }

    let report = collector.finish(su.s);
    let mut j = report.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("cmd".into(), Json::Str("uq_evaluate".into()));
        m.insert("arch".into(), Json::Str(su.arch.clone()));
        m.insert(
            "accuracy".into(),
            Json::Num(correct_all as f64 / su.test.n.max(1) as f64),
        );
        m.insert(
            "accuracy_accept".into(),
            Json::Num(correct_accept as f64 / accept_n.max(1) as f64),
        );
        m.insert(
            "noise_abstain_pct".into(),
            Json::Num(
                noise_abstain as f64 * 100.0 / noise.n.max(1) as f64,
            ),
        );
        m.insert(
            "temperature".into(),
            Json::Num(risk.scaler.temperature),
        );
    }
    let line = jsonio::write(&j);
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("uq_report_{}.json", su.arch))
    });
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, format!("{line}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    if args.flag("json") {
        println!("{line}");
    } else {
        println!("{}", report.render());
        println!(
            "\x20 accuracy              {:.3} overall, {:.3} on accepted",
            correct_all as f64 / su.test.n.max(1) as f64,
            correct_accept as f64 / accept_n.max(1) as f64
        );
        println!(
            "\x20 noise abstain rate    {:.1}% of {} OOD probes",
            noise_abstain as f64 * 100.0 / noise.n.max(1) as f64,
            noise.n
        );
        println!("saved {}", out.display());
    }
    Ok(())
}

/// `repro uq report`: render a saved evaluation report.
fn cmd_uq_report(args: &Args) -> Result<()> {
    let arch = args.get("arch").unwrap_or("classify_h8_nl1_Y");
    let path = args.get("file").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("uq_report_{arch}.json"))
    });
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!(
            "{} missing — run `repro uq evaluate` first",
            path.display()
        )
    })?;
    let line = text
        .lines()
        .find(|l| l.trim_start().starts_with('{'))
        .context("no JSON object in report file")?
        .trim();
    if args.flag("json") {
        println!("{line}");
        return Ok(());
    }
    let j = jsonio::parse(line)?;
    let report = UqReport::from_json(&j)?;
    println!("{}", report.render());
    if let Some(a) = j.get("accuracy").and_then(Json::as_f64) {
        println!("\x20 accuracy (all)        {a:.3}");
    }
    if let Some(a) = j.get("accuracy_accept").and_then(Json::as_f64) {
        println!("\x20 accuracy (accepted)   {a:.3}");
    }
    if let Some(a) = j.get("noise_abstain_pct").and_then(Json::as_f64) {
        println!("\x20 noise abstain         {a:.1}%");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ROADMAP PR 3 finding b: an oversized `--subset` used to clamp
    /// the evaluate window back onto the calibration window (offset
    /// `min(test.n - 1)`), silently making the "held-out" metrics
    /// in-sample. It must now be a hard error with actionable guidance.
    #[test]
    fn uq_window_rejects_oversized_subsets_instead_of_clamping() {
        // Calibration window (0) always starts at 0 and truncates.
        assert_eq!(uq_window(500, 200, 0).unwrap(), (0, 200));
        assert_eq!(uq_window(500, 600, 0).unwrap(), (0, 500));
        // Evaluate window (1): disjoint, may truncate at the end.
        assert_eq!(uq_window(500, 200, 1).unwrap(), (200, 400));
        assert_eq!(uq_window(500, 400, 1).unwrap(), (400, 500));
        // Oversized: previously collapsed onto beats [499, 500); now a
        // hard error that names the largest safe subset.
        let err = uq_window(500, 600, 1).unwrap_err().to_string();
        assert!(err.contains("only 500 beats"), "{err}");
        assert!(err.contains("--subset <= 250"), "{err}");
        // Exactly at the boundary is still an error (start == n).
        assert!(uq_window(500, 500, 1).is_err());
        // The suggested bound is itself valid.
        assert!(uq_window(500, 250, 1).is_ok());
    }

    #[test]
    fn precision_flag_parses_presets_and_overrides() {
        let (_, args) = Args::parse(&[
            "serve".into(),
            "--precision".into(),
            "q8,l1=q16".into(),
        ]);
        let p = args.precision().unwrap();
        assert_eq!(p.name(), "q8+l1=q16");
        let (_, args) = Args::parse(&["serve".into()]);
        assert!(args.precision().unwrap().is_q16());
        let (_, args) = Args::parse(&[
            "serve".into(),
            "--precision".into(),
            "q9".into(),
        ]);
        assert!(args.precision().is_err());
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    println!("artifacts in {}:", dir.display());
    let metas: Vec<(String, String, usize)> = rt
        .manifest
        .artifacts
        .iter()
        .map(|a| (a.name.clone(), a.kind.clone(), a.args.len()))
        .collect();
    for (name, kind, nargs) in metas {
        println!("  {name:<44} {kind:<8} {nargs} args");
    }
    // Smoke-compile the first artifact.
    if let Some(first) =
        rt.manifest.artifacts.first().map(|a| a.name.clone())
    {
        rt.load(&first)?;
        println!("compiled {first} OK");
    }
    Ok(())
}
