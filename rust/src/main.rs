//! `repro` — the leader CLI for the Bayesian-RNN-on-FPGA reproduction.
//!
//! Subcommands:
//!   sweep   run the algorithmic DSE sweep, write the lookup table
//!   dse     run the optimisation framework over a lookup table (Tables V/VI)
//!   train   train one architecture (native engine or PJRT AOT train step)
//!   eval    evaluate a trained checkpoint (float / fixed-point FPGA sim)
//!   serve   run the serving coordinator on synthetic ECG traffic
//!   info    show artifact manifest + platform
//!
//! Arg parsing is hand-rolled (`--key value` / flags) — no clap in this
//! offline environment (see Cargo.toml).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};
use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::coordinator::loadgen::PoissonTrace;
use bayes_rnn_fpga::coordinator::{
    BatchPolicy, Engine, Fleet, FleetConfig, RouterPolicy,
};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::reuse_search;
use bayes_rnn_fpga::dse::{LookupTable, Optimizer};
use bayes_rnn_fpga::fpga::accel::Accelerator;
use bayes_rnn_fpga::hwmodel::ZC706;
use bayes_rnn_fpga::nn::model::Model;
use bayes_rnn_fpga::nn::Params;
use bayes_rnn_fpga::rng::Rng;
use bayes_rnn_fpga::runtime::Runtime;
use bayes_rnn_fpga::tensor::{load_tensors, save_tensors, Tensor};
use bayes_rnn_fpga::train::eval::{eval_anomaly, eval_classify, ModelPredictor};
use bayes_rnn_fpga::train::sweep::{self, SweepOpts};
use bayes_rnn_fpga::train::{NativeTrainer, PjrtTrainer, TrainOpts};

/// Tiny `--key value` parser: positional subcommand + options.
struct Args {
    opts: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> (Option<String>, Args) {
        let mut opts = HashMap::new();
        let mut cmd = None;
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    opts.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                if cmd.is_none() {
                    cmd = Some(a.clone());
                }
                i += 1;
            }
        }
        (cmd, Args { opts })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    fn task(&self) -> Result<Task> {
        self.get("task")
            .unwrap_or("classify")
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))
    }

    fn artifacts_dir(&self) -> PathBuf {
        PathBuf::from(self.get("artifacts").unwrap_or("artifacts"))
    }
}

/// Parse "anomaly_h16_nl2_YNYN"-style names back into a config.
fn parse_arch(name: &str) -> Result<ArchConfig> {
    let parts: Vec<&str> = name.split('_').collect();
    anyhow::ensure!(parts.len() == 4, "arch name like anomaly_h16_nl2_YNYN");
    let task: Task =
        parts[0].parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let h: usize = parts[1].trim_start_matches('h').parse()?;
    let nl: usize = parts[2].trim_start_matches("nl").parse()?;
    Ok(ArchConfig::new(task, h, nl, parts[3]))
}

fn print_usage() {
    eprintln!(
        "repro — Bayesian-RNN-on-FPGA reproduction CLI

usage: repro <subcommand> [--key value | --flag] ...

subcommands:
  sweep   run the algorithmic DSE sweep, write the lookup table
          [--task anomaly|classify] [--full] [--epochs N]
          [--train-subset N] [--test-subset N] [--samples S] [--out PATH]
  dse     optimise over a lookup table (Tables V/VI)
          [--task T] [--lookup PATH] [--batch N] [--samples S]
  train   train one architecture
          --arch NAME [--backend native|pjrt] [--epochs N] [--batch N]
          [--lr F] [--seed N] [--out PATH]
  eval    evaluate a trained checkpoint (float / --fixed FPGA sim)
          --arch NAME [--weights PATH] [--samples S] [--test-subset N]
          [--fixed]
  serve   run the serving fleet on synthetic ECG traffic
          [--arch NAME] [--engines N] [--router rr|least-loaded|mc-shard]
          [--backend fpga|gpu|pjrt|mix] [--samples S] [--requests N]
          [--rate REQ_PER_S] [--queue-depth N] [--batch N] [--shed]
          [--seed N] [--json]
          (missing weights fall back to a deterministic random init —
           synthetic load mode, used by the bench harness)
  info    show artifact manifest + platform
  help    this message (also: --help on any subcommand)

common flags: --artifacts DIR (default ./artifacts), --weights PATH"
    );
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, args) = Args::parse(&argv);
    if args.flag("help") {
        print_usage();
        return Ok(());
    }
    match cmd.as_deref() {
        Some("sweep") => cmd_sweep(&args),
        Some("dse") => cmd_dse(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        Some("help") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            print_usage();
            anyhow::bail!("unknown subcommand {other:?}");
        }
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let task = args.task()?;
    let opts = SweepOpts {
        full_grid: args.flag("full"),
        epochs: args.usize_or("epochs", 25),
        train_subset: args.usize_or("train-subset", 500),
        test_subset: args.usize_or("test-subset", 400),
        mc_samples: args.usize_or("samples", 10),
        ..Default::default()
    };
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("lookup_{}.json", task.as_str()))
    });
    let mut table = if let Ok(t) = LookupTable::load(&out) {
        println!("extending existing table {}", out.display());
        t
    } else {
        LookupTable::new()
    };
    let t0 = std::time::Instant::now();
    sweep::run(task, &opts, &mut table, |done, total, name| {
        println!("[{done}/{total}] {name}");
    });
    table.save(&out)?;
    println!(
        "sweep done in {:.1}s -> {} ({} entries)",
        t0.elapsed().as_secs_f64(),
        out.display(),
        table.entries.len()
    );
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let task = args.task()?;
    let path = args.get("lookup").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("lookup_{}.json", task.as_str()))
    });
    let lookup = LookupTable::load(&path).with_context(|| {
        format!("run `repro sweep --task {}` first", task.as_str())
    })?;
    let mut opt = Optimizer::new(&ZC706, &lookup);
    opt.batch = args.usize_or("batch", 50);
    opt.mc_samples = args.usize_or("samples", 30);
    println!(
        "{:<14} {:>20} {:>12} {:>4} {:>11} {:>11} {:>7}  metrics",
        "Mode", "A:{H,NL,B}", "R:{x,h,d}", "S", "FPGA [ms]", "GPU [ms]",
        "P [W]"
    );
    for mode in Optimizer::modes_for(task) {
        match opt.optimize(task, mode) {
            Some(c) => {
                let metr: Vec<String> = c
                    .metrics
                    .iter()
                    .map(|(k, v)| format!("{k}={v:.3}"))
                    .collect();
                println!(
                    "{:<14} {:>20} {:>12} {:>4} {:>11.2} {:>11.2} {:>7.2}  {}",
                    c.mode,
                    format!(
                        "{{{},{},{}}}",
                        c.arch.hidden,
                        c.arch.nl,
                        c.arch.bayes_str()
                    ),
                    format!(
                        "{{{},{},{}}}",
                        c.reuse.rx, c.reuse.rh, c.reuse.rd
                    ),
                    c.s,
                    c.fpga_latency_ms,
                    c.gpu_latency_ms,
                    c.fpga_watts,
                    metr.join(" ")
                );
            }
            None => {
                println!("{:<14} (no feasible configuration)", mode.name())
            }
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let arch = args.get("arch").context("--arch NAME required")?;
    let cfg = parse_arch(arch)?;
    let epochs = args.usize_or("epochs", 60);
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("{arch}.weights.brt"))
    });
    let backend = args.get("backend").unwrap_or("native");

    let (train_set, _) = match cfg.task {
        Task::Anomaly => data::anomaly_splits(0),
        Task::Classify => data::splits(0),
    };
    let t0 = std::time::Instant::now();
    let params: Params = match backend {
        "native" => {
            let mut tr = NativeTrainer::new(
                cfg.clone(),
                TrainOpts {
                    epochs,
                    batch: args.usize_or("batch", 64),
                    lr: args.f32_or(
                        "lr",
                        if cfg.task == Task::Anomaly { 1e-2 } else { 5e-3 },
                    ),
                    seed: args.usize_or("seed", 0) as u64,
                },
            );
            tr.fit(&train_set);
            println!(
                "native training: {} epochs, loss {:.4} -> {:.4}",
                epochs,
                tr.loss_history[0],
                tr.final_loss()
            );
            tr.model.params
        }
        "pjrt" => {
            let mut rt = Runtime::new(&args.artifacts_dir())?;
            let batch = args.usize_or("batch", 64);
            let mut tr = PjrtTrainer::new(
                &mut rt,
                arch,
                batch,
                args.f32_or("lr", 1e-3),
                args.usize_or("seed", 0) as u64,
            )?;
            tr.fit(&train_set, epochs)?;
            println!(
                "pjrt training: {} epochs, loss {:.4} -> {:.4}",
                epochs,
                tr.loss_history.first().unwrap_or(&f32::NAN),
                tr.loss_history.last().unwrap_or(&f32::NAN)
            );
            tr.params
        }
        other => anyhow::bail!("unknown backend {other:?}"),
    };
    let named: Vec<(String, Tensor)> = cfg
        .param_names()
        .into_iter()
        .zip(params.tensors.iter().cloned())
        .collect();
    save_tensors(&out, &named)?;
    println!(
        "saved {} ({} params) in {:.1}s",
        out.display(),
        cfg.num_weights(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn load_model(args: &Args, cfg: &ArchConfig, arch: &str) -> Result<Model> {
    let path = args.get("weights").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("{arch}.weights.brt"))
    });
    let named = load_tensors(&path).with_context(|| {
        format!("{} missing — run `repro train --arch {arch}`", path.display())
    })?;
    Ok(Model::new(
        cfg.clone(),
        Params { tensors: named.into_iter().map(|(_, t)| t).collect() },
    ))
}

fn cmd_eval(args: &Args) -> Result<()> {
    let arch = args.get("arch").context("--arch NAME required")?;
    let cfg = parse_arch(arch)?;
    let model = load_model(args, &cfg, arch)?;
    let s = args.usize_or("samples", 30);
    let subset = args.usize_or("test-subset", 500);
    match cfg.task {
        Task::Anomaly => {
            let (_, test) = data::anomaly_splits(0);
            let te =
                test.subset(&(0..subset.min(test.n)).collect::<Vec<_>>());
            if args.flag("fixed") {
                let reuse = reuse_search(&cfg, &ZC706)
                    .context("does not fit ZC706")?;
                let mut acc = Accelerator::new(&cfg, &model.params, reuse, 7);
                let rep = eval_anomaly(&mut acc, &te, s);
                println!(
                    "fixed-point  AUC {:.3}  AP {:.3}  ACC {:.3}",
                    rep.auc, rep.ap, rep.accuracy
                );
            }
            let mut p = ModelPredictor::new(&model, 7);
            let rep = eval_anomaly(&mut p, &te, s);
            println!(
                "float        AUC {:.3}  AP {:.3}  ACC {:.3}  \
                 (rmse normal {:.3} vs anomalous {:.3})",
                rep.auc,
                rep.ap,
                rep.accuracy,
                rep.mean_rmse_normal,
                rep.mean_rmse_anomalous
            );
        }
        Task::Classify => {
            let (_, test) = data::splits(0);
            let te =
                test.subset(&(0..subset.min(test.n)).collect::<Vec<_>>());
            let noise = data::gaussian_noise(50, 0);
            if args.flag("fixed") {
                let reuse = reuse_search(&cfg, &ZC706)
                    .context("does not fit ZC706")?;
                let mut acc = Accelerator::new(&cfg, &model.params, reuse, 7);
                let rep = eval_classify(&mut acc, &te, &noise, s);
                println!(
                    "fixed-point  ACC {:.3}  AP {:.3}  AR {:.3}  H {:.3} nats",
                    rep.accuracy, rep.ap, rep.ar, rep.noise_entropy
                );
            }
            let mut p = ModelPredictor::new(&model, 7);
            let rep = eval_classify(&mut p, &te, &noise, s);
            println!(
                "float        ACC {:.3}  AP {:.3}  AR {:.3}  H {:.3} nats",
                rep.accuracy, rep.ap, rep.ar, rep.noise_entropy
            );
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Default arch lets the bench harness drive a bare checkout.
    let arch =
        args.get("arch").unwrap_or("classify_h8_nl1_Y").to_string();
    let cfg = parse_arch(&arch)?;
    let s =
        if cfg.is_bayesian() { args.usize_or("samples", 30) } else { 1 };
    let n_req = args.usize_or("requests", 100);
    let n_engines = args.usize_or("engines", 1).max(1);
    let router: RouterPolicy = args
        .get("router")
        .unwrap_or("rr")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    // --engine kept as a legacy alias for --backend.
    let backend = args
        .get("backend")
        .or_else(|| args.get("engine"))
        .unwrap_or("fpga")
        .to_string();
    // MC-shard merges shards numerically; mixing fixed-point FPGA and
    // float GPU samples in one reduction would break the documented
    // engine-count invariance.
    anyhow::ensure!(
        !(backend == "mix" && router == RouterPolicy::McShard),
        "--backend mix cannot be combined with --router mc-shard \
         (shards from fixed-point and float engines would be merged)"
    );
    let batch = args.usize_or("batch", 8);
    let queue_depth = args.usize_or("queue-depth", 256);
    let shed = args.flag("shed");
    let json_out = args.flag("json");
    let seed = args.usize_or("seed", 3) as u64;
    let artifacts = args.artifacts_dir();

    // Trained weights if available; otherwise a deterministic random
    // init so load runs (and their predictions) are reproducible
    // without artifacts — the bench harness relies on this.
    let model = match load_model(args, &cfg, &arch) {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "note: {e:#}; serving untrained weights (synthetic mode)"
            );
            Model::init(cfg.clone(), &mut Rng::new(seed ^ 0xC0FFEE))
        }
    };

    // All engines share one design seed: MC-shard predictions are then
    // identical for any engine count (same request => same sample set).
    let params = model.params.tensors.clone();
    let mut factories: Vec<Box<dyn FnOnce() -> Engine + Send>> =
        Vec::with_capacity(n_engines);
    for j in 0..n_engines {
        let kind = match backend.as_str() {
            "mix" => (if j % 2 == 0 { "fpga" } else { "gpu" }).to_string(),
            other => other.to_string(),
        };
        let cfg2 = cfg.clone();
        let p2 = params.clone();
        let arts = artifacts.clone();
        factories.push(Box::new(move || match kind.as_str() {
            "gpu" => Engine::gpu(
                Model::new(cfg2.clone(), Params { tensors: p2.clone() }),
                s,
                seed,
            ),
            "pjrt" => {
                let rt = Runtime::new(&arts).expect("artifacts");
                Engine::pjrt(rt, &cfg2.name(), &p2, s, seed)
                    .expect("pjrt engine")
            }
            _ => {
                let reuse = reuse_search(&cfg2, &ZC706).expect("fits ZC706");
                let m = Model::new(
                    cfg2.clone(),
                    Params { tensors: p2.clone() },
                );
                Engine::fpga(&cfg2, &m, reuse, s, seed)
            }
        }));
    }

    let policy = match backend.as_str() {
        "gpu" | "pjrt" => {
            BatchPolicy::batched(batch, std::time::Duration::from_millis(2))
        }
        _ => BatchPolicy::stream(),
    };
    let mut fleet = Fleet::start(
        FleetConfig {
            engines: n_engines,
            router,
            policy,
            queue_depth,
            shed,
            samples: s,
        },
        factories,
    );

    let (_, test) = match cfg.task {
        Task::Anomaly => data::anomaly_splits(0),
        Task::Classify => data::splits(0),
    };
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(n_req);
    if let Some(rate) = args.get("rate").and_then(|v| v.parse::<f64>().ok())
    {
        // Open-loop Poisson arrivals: exposes the latency knee and, with
        // --shed, the admission-control behaviour under overload.
        let trace = PoissonTrace::generate(rate, n_req, &test, seed);
        let start = std::time::Instant::now();
        for a in &trace.arrivals {
            if let Some(wait) = a.at.checked_sub(start.elapsed()) {
                if !wait.is_zero() {
                    std::thread::sleep(wait);
                }
            }
            if let Some(t) = fleet.submit(test.beat(a.beat_idx).to_vec()) {
                tickets.push(t);
            }
        }
    } else {
        // Closed loop: submit everything, then wait.
        for i in 0..n_req {
            if let Some(t) = fleet.submit(test.beat(i % test.n).to_vec()) {
                tickets.push(t);
            }
        }
    }

    // Checksums over the first 8 responses (submit order): the bench
    // harness compares these across engine counts to verify the
    // MC-shard reduction numerically.
    let mut pred_checksum = 0f64;
    let mut unc_checksum = 0f64;
    for (i, t) in tickets.into_iter().enumerate() {
        let resp = fleet.wait(t)?;
        if i < 8 {
            pred_checksum +=
                resp.prediction.mean.iter().map(|&v| v as f64).sum::<f64>();
            unc_checksum +=
                resp.prediction.std.iter().map(|&v| v as f64).sum::<f64>();
        }
    }
    let wall = t0.elapsed();
    let summary = fleet.join();
    let throughput = if wall.as_secs_f64() > 0.0 {
        summary.served as f64 / wall.as_secs_f64()
    } else {
        0.0
    };
    let engine_stats = summary.engine_stats();

    if json_out {
        // Single-line JSON for the process-based bench harness.
        println!(
            "{{\"cmd\":\"serve\",\"arch\":\"{arch}\",\"engines\":{n_engines},\
             \"router\":\"{}\",\"backend\":\"{backend}\",\"samples\":{s},\
             \"requests\":{n_req},\"served\":{},\"rejected\":{},\
             \"wall_s\":{:.6},\"throughput_rps\":{:.3},\
             \"e2e_ms\":{{\"mean\":{:.4},\"p50\":{:.4},\"p99\":{:.4},\
             \"max\":{:.4}}},\
             \"engine_ms\":{{\"mean\":{:.4},\"p99\":{:.4}}},\
             \"batches\":{},\"pred_checksum\":{:.6},\
             \"unc_checksum\":{:.6}}}",
            router.as_str(),
            summary.served,
            summary.rejected,
            wall.as_secs_f64(),
            throughput,
            summary.e2e.mean_ms(),
            summary.e2e.percentile_ms(50.0),
            summary.e2e.percentile_ms(99.0),
            summary.e2e.max_ms(),
            engine_stats.mean_ms(),
            engine_stats.percentile_ms(99.0),
            summary.batches(),
            pred_checksum,
            unc_checksum,
        );
        return Ok(());
    }

    println!(
        "fleet: {n_engines} x {backend} engines, router {}, S={s}{}",
        router.as_str(),
        if shed { ", shedding on" } else { "" }
    );
    println!(
        "served {} / {} requests in {:.2}s  ({throughput:.1} req/s)  \
         rejected {}",
        summary.served,
        n_req,
        wall.as_secs_f64(),
        summary.rejected
    );
    println!(
        "e2e    mean {:.3} ms  p50 {:.3}  p99 {:.3}  max {:.3}",
        summary.e2e.mean_ms(),
        summary.e2e.percentile_ms(50.0),
        summary.e2e.percentile_ms(99.0),
        summary.e2e.max_ms()
    );
    println!(
        "engine mean {:.3} ms  batches {} (avg size {:.2})",
        engine_stats.mean_ms(),
        summary.batches(),
        if summary.batches() > 0 {
            summary.items() as f64 / summary.batches() as f64
        } else {
            0.0
        }
    );
    for (j, e) in summary.per_engine.iter().enumerate() {
        println!(
            "  engine[{j}]  items {:<6} batches {:<6} model mean {:.3} ms",
            e.served, e.batches, e.engine.mean_ms()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    println!("artifacts in {}:", dir.display());
    let metas: Vec<(String, String, usize)> = rt
        .manifest
        .artifacts
        .iter()
        .map(|a| (a.name.clone(), a.kind.clone(), a.args.len()))
        .collect();
    for (name, kind, nargs) in metas {
        println!("  {name:<44} {kind:<8} {nargs} args");
    }
    // Smoke-compile the first artifact.
    if let Some(first) =
        rt.manifest.artifacts.first().map(|a| a.name.clone())
    {
        rt.load(&first)?;
        println!("compiled {first} OK");
    }
    Ok(())
}
