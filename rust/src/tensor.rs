//! Minimal dense f32 tensor + binary serialisation shared across the crate
//! (weights, datasets, lookup tables). Deliberately dependency-free: the
//! paper's stack needs shapes up to rank 3 and contiguous row-major data,
//! nothing more.

use std::io::{Read, Write};
use std::path::Path;

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: (0..n).map(&mut f).collect() }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Flat index of a rank-2 element.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Flat index of a rank-3 element.
    #[inline]
    pub fn at3(&self, i: usize, j: usize, k: usize) -> f32 {
        debug_assert_eq!(self.rank(), 3);
        self.data[(i * self.shape[1] + j) * self.shape[2] + k]
    }

    /// Contiguous row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Contiguous slice `[i, j, :]` of a rank-3 tensor.
    pub fn slice3(&self, i: usize, j: usize) -> &[f32] {
        let (d1, d2) = (self.shape[1], self.shape[2]);
        let off = (i * d1 + j) * d2;
        &self.data[off..off + d2]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Euclidean norm (used by grad-clip cross-checks in tests).
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Max |a - b| against another tensor of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

// ---------------------------------------------------------------------------
// Binary container: `BRT1` magic, u32 count, then per tensor: u32 name-len,
// name bytes, u32 rank, u64 dims, f32 LE data. Used for checkpoints and
// dataset caches.
// ---------------------------------------------------------------------------

const MAGIC: &[u8; 4] = b"BRT1";

pub fn save_tensors(
    path: &Path,
    tensors: &[(String, Tensor)],
) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load_tensors(path: &Path) -> std::io::Result<Vec<(String, Tensor)>> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic: not a BRT1 tensor file",
        ));
    }
    let count = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let rank = read_u32(&mut f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        out.push((
            String::from_utf8(name).map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, e)
            })?,
            Tensor::new(shape, data),
        ));
    }
    Ok(out)
}

fn read_u32(f: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_fn(&[2, 3], |i| i as f32);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        let t3 = Tensor::from_fn(&[2, 3, 4], |i| i as f32);
        assert_eq!(t3.at3(1, 2, 3), 23.0);
        assert_eq!(t3.slice3(0, 1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn reshape_keeps_data() {
        let t = Tensor::from_fn(&[6], |i| i as f32).reshape(&[2, 3]);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("brt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.brt");
        let tensors = vec![
            ("a".to_string(), Tensor::from_fn(&[3, 2], |i| i as f32 * 0.5)),
            ("b.scalar".to_string(), Tensor::scalar(7.25)),
            ("empty_rank1".to_string(), Tensor::zeros(&[4])),
        ];
        save_tensors(&path, &tensors).unwrap();
        let loaded = load_tensors(&path).unwrap();
        assert_eq!(loaded, tensors);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("brt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.brt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load_tensors(&path).is_err());
    }

    #[test]
    fn stats_helpers() {
        let a = Tensor::new(vec![3], vec![3.0, 4.0, 0.0]);
        assert!((a.norm() - 5.0).abs() < 1e-6);
        let b = Tensor::new(vec![3], vec![3.0, 4.5, 0.0]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
    }
}
