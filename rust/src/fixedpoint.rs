//! 16-bit fixed-point substrate (paper Sec. IV-A/V-B).
//!
//! The accelerator quantises weights and activations to 16-bit fixed point
//! and keeps the LSTM cell state `c` in 32 bits ("16-bit representation,
//! except c which is represented in 32-bit"). We use Q6.10 for the 16-bit
//! path (range [-32, 32), LSB 2^-10 ≈ 1e-3 — comfortably covering
//! z-normalised ECG and gate pre-activations) and Q12.20 for the 32-bit
//! cell path. Activation functions are BRAM-style lookup tables over a
//! precomputed input range, exactly like the hardware (Sec. III-A).
//!
//! All arithmetic saturates (no wrap-around), matching DSP-block behaviour
//! with saturation logic.

/// Fractional bits of the 16-bit path (Q6.10).
pub const FRAC16: i32 = 10;
/// Fractional bits of the 32-bit cell path (Q12.20).
pub const FRAC32: i32 = 20;

/// 16-bit fixed-point value, Q6.10.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Fx16(pub i16);

/// 32-bit fixed-point value, Q12.20 (the cell-state path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Fx32(pub i32);

impl Fx16 {
    pub const ZERO: Fx16 = Fx16(0);
    pub const ONE: Fx16 = Fx16(1 << FRAC16);

    /// Quantise an f32 (round-to-nearest, saturate).
    #[inline]
    pub fn from_f32(v: f32) -> Self {
        let scaled = (v as f64 * (1i64 << FRAC16) as f64).round();
        Fx16(scaled.clamp(i16::MIN as f64, i16::MAX as f64) as i16)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / (1 << FRAC16) as f32
    }

    #[inline]
    pub fn saturating_add(self, rhs: Fx16) -> Fx16 {
        Fx16(self.0.saturating_add(rhs.0))
    }

    /// Fixed-point multiply: (a*b) >> FRAC16 with rounding and saturation —
    /// one DSP48 multiplier in the hardware.
    #[inline]
    pub fn saturating_mul(self, rhs: Fx16) -> Fx16 {
        let prod = self.0 as i32 * rhs.0 as i32;
        let rounded = (prod + (1 << (FRAC16 - 1))) >> FRAC16;
        Fx16(rounded.clamp(i16::MIN as i32, i16::MAX as i32) as i16)
    }

    /// Widen to the 32-bit cell path.
    #[inline]
    pub fn widen(self) -> Fx32 {
        Fx32((self.0 as i32) << (FRAC32 - FRAC16))
    }
}

impl Fx32 {
    pub const ZERO: Fx32 = Fx32(0);

    #[inline]
    pub fn from_f32(v: f32) -> Self {
        let scaled = (v as f64 * (1i64 << FRAC32) as f64).round();
        Fx32(scaled.clamp(i32::MIN as f64, i32::MAX as f64) as i32)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / (1u32 << FRAC32) as f32
    }

    #[inline]
    pub fn saturating_add(self, rhs: Fx32) -> Fx32 {
        Fx32(self.0.saturating_add(rhs.0))
    }

    /// Multiply two 16-bit operands into the 32-bit path (f_t * c_{t-1}
    /// uses two cascaded DSPs in the paper — 16x32 -> 32).
    #[inline]
    pub fn mul_fx16(self, rhs: Fx16) -> Fx32 {
        let prod = self.0 as i64 * rhs.0 as i64;
        let rounded = (prod + (1 << (FRAC16 - 1))) >> FRAC16;
        Fx32(rounded.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
    }

    /// Narrow back to the 16-bit path (saturating).
    #[inline]
    pub fn narrow(self) -> Fx16 {
        let shifted =
            (self.0 + (1 << (FRAC32 - FRAC16 - 1))) >> (FRAC32 - FRAC16);
        Fx16(shifted.clamp(i16::MIN as i32, i16::MAX as i32) as i16)
    }
}

/// 16-bit MAC accumulator for MVM engines: products are accumulated in a
/// wide register (as DSP48 cascades do) and narrowed once at the end —
/// avoids per-term quantisation error.
#[derive(Debug, Clone, Copy, Default)]
pub struct MacAcc(i64);

impl MacAcc {
    #[inline]
    pub fn new() -> Self {
        MacAcc(0)
    }

    #[inline]
    pub fn mac(&mut self, a: Fx16, b: Fx16) {
        self.0 += a.0 as i64 * b.0 as i64; // Q(2*FRAC16)
    }

    /// Finish: add bias (Q10) and narrow to Fx16 with rounding/saturation.
    #[inline]
    pub fn finish(self, bias: Fx16) -> Fx16 {
        let with_bias = self.0 + ((bias.0 as i64) << FRAC16);
        let rounded = (with_bias + (1 << (FRAC16 - 1))) >> FRAC16;
        Fx16(rounded.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
    }
}

// ---------------------------------------------------------------------------
// BRAM-style activation LUTs (Sec. III-A): sigmoid/tanh precomputed over a
// fixed input range, indexed by the upper bits of the fixed-point input.
// ---------------------------------------------------------------------------

/// Lookup-table activation over [-RANGE, RANGE] with 2^BITS entries.
pub struct ActLut {
    table: Vec<Fx16>,
    /// Input clamp range in fixed-point raw units.
    lo_raw: i32,
    hi_raw: i32,
    shift: i32,
}

/// LUT input range: |x| <= 8 saturates both sigmoid and tanh to <1 LSB of
/// the 16-bit output.
pub const LUT_RANGE: f32 = 8.0;
/// log2(entries): 1024-entry tables fit one BRAM18 each at 16-bit width.
pub const LUT_BITS: u32 = 10;

impl ActLut {
    pub fn new(f: impl Fn(f64) -> f64) -> Self {
        let entries = 1usize << LUT_BITS;
        let lo_raw = Fx16::from_f32(-LUT_RANGE).0 as i32;
        let hi_raw = Fx16::from_f32(LUT_RANGE).0 as i32;
        let span = (hi_raw - lo_raw) as i64;
        // Each LUT bucket covers `span / entries` raw units; precompute the
        // function at each bucket midpoint.
        let mut table = Vec::with_capacity(entries);
        for i in 0..entries {
            let raw_mid = lo_raw as i64
                + (span * (2 * i as i64 + 1)) / (2 * entries as i64);
            let x = raw_mid as f64 / (1 << FRAC16) as f64;
            table.push(Fx16::from_f32(f(x) as f32));
        }
        // span / entries as a shift: span = 16 * 2^10 raw = 2^14; entries =
        // 2^10 -> 16 raw units per bucket = shift 4.
        let shift = (span as f64 / entries as f64).log2().round() as i32;
        Self { table, lo_raw, hi_raw, shift }
    }

    pub fn sigmoid() -> Self {
        Self::new(|x| 1.0 / (1.0 + (-x).exp()))
    }

    pub fn tanh() -> Self {
        Self::new(|x| x.tanh())
    }

    /// One BRAM read: clamp, index by upper bits, return table entry.
    #[inline]
    pub fn eval(&self, x: Fx16) -> Fx16 {
        let raw = (x.0 as i32).clamp(self.lo_raw, self.hi_raw - 1);
        let idx = ((raw - self.lo_raw) >> self.shift) as usize;
        self.table[idx]
    }

    pub fn entries(&self) -> usize {
        self.table.len()
    }
}

/// Quantise an f32 slice to Fx16.
pub fn quantize(v: &[f32]) -> Vec<Fx16> {
    v.iter().map(|&x| Fx16::from_f32(x)).collect()
}

/// Dequantise back to f32 (for metric evaluation of the quantised model).
pub fn dequantize(v: &[Fx16]) -> Vec<f32> {
    v.iter().map(|x| x.to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_precision() {
        for &v in &[0.0f32, 1.0, -1.0, 0.123, -3.875, 7.5, -20.25] {
            let q = Fx16::from_f32(v);
            assert!(
                (q.to_f32() - v).abs() <= 0.5 / (1 << FRAC16) as f32 + 1e-6,
                "v={v} q={}",
                q.to_f32()
            );
        }
    }

    #[test]
    fn saturation_at_range_edges() {
        assert_eq!(Fx16::from_f32(1e9).0, i16::MAX);
        assert_eq!(Fx16::from_f32(-1e9).0, i16::MIN);
        let big = Fx16::from_f32(31.0);
        assert_eq!(big.saturating_add(big).0, i16::MAX);
    }

    #[test]
    fn mul_matches_float() {
        let a = Fx16::from_f32(1.5);
        let b = Fx16::from_f32(-2.25);
        let p = a.saturating_mul(b).to_f32();
        assert!((p - (-3.375)).abs() < 2.0 / (1 << FRAC16) as f32);
    }

    #[test]
    fn widen_narrow_roundtrip() {
        let a = Fx16::from_f32(2.375);
        assert_eq!(a.widen().narrow(), a);
        let c = Fx32::from_f32(-1.8125);
        assert!((c.narrow().to_f32() - -1.8125).abs() < 1e-3);
    }

    #[test]
    fn fx32_mul_fx16() {
        let c = Fx32::from_f32(0.5);
        let f = Fx16::from_f32(0.5);
        assert!((c.mul_fx16(f).to_f32() - 0.25).abs() < 1e-5);
    }

    #[test]
    fn mac_accumulator_exactness() {
        // MAC of quantised values must equal exact integer math.
        let xs = [0.5f32, -0.25, 1.75, 0.125];
        let ws = [1.0f32, 0.5, -0.5, 2.0];
        let mut acc = MacAcc::new();
        for (&x, &w) in xs.iter().zip(ws.iter()) {
            acc.mac(Fx16::from_f32(x), Fx16::from_f32(w));
        }
        let got = acc.finish(Fx16::from_f32(0.25)).to_f32();
        let want: f32 =
            xs.iter().zip(ws.iter()).map(|(x, w)| x * w).sum::<f32>() + 0.25;
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    #[test]
    fn sigmoid_lut_accuracy() {
        let lut = ActLut::sigmoid();
        for i in -800..800 {
            let x = i as f32 * 0.01;
            let got = lut.eval(Fx16::from_f32(x)).to_f32();
            let want = 1.0 / (1.0 + (-x).exp());
            assert!(
                (got - want).abs() < 0.01,
                "sigmoid({x}) LUT={got} exact={want}"
            );
        }
    }

    #[test]
    fn tanh_lut_accuracy() {
        let lut = ActLut::tanh();
        for i in -800..800 {
            let x = i as f32 * 0.01;
            let got = lut.eval(Fx16::from_f32(x)).to_f32();
            assert!(
                (got - x.tanh()).abs() < 0.02,
                "tanh({x}) LUT={got} exact={}",
                x.tanh()
            );
        }
    }

    #[test]
    fn lut_saturates_out_of_range() {
        let lut = ActLut::sigmoid();
        assert!((lut.eval(Fx16::from_f32(20.0)).to_f32() - 1.0).abs() < 0.01);
        assert!(lut.eval(Fx16::from_f32(-20.0)).to_f32() < 0.01);
        assert_eq!(lut.entries(), 1 << LUT_BITS);
    }

    #[test]
    fn quantize_dequantize_slice() {
        let v = vec![0.1f32, -0.9, 2.5];
        let d = dequantize(&quantize(&v));
        for (a, b) in v.iter().zip(d.iter()) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    /// Property sweep: quantisation error bound, add commutativity,
    /// multiply sign law and widen/narrow idempotence over random values.
    #[test]
    fn property_sweep_random_values() {
        use crate::rng::Rng;
        let mut rng = Rng::new(77);
        let lsb = 1.0 / (1 << FRAC16) as f32;
        for _ in 0..2000 {
            let a = rng.uniform_in(-20.0, 20.0) as f32;
            let b = rng.uniform_in(-20.0, 20.0) as f32;
            let qa = Fx16::from_f32(a);
            let qb = Fx16::from_f32(b);
            // Rounding bound.
            assert!((qa.to_f32() - a).abs() <= 0.5 * lsb + 1e-6);
            // Commutativity.
            assert_eq!(qa.saturating_add(qb), qb.saturating_add(qa));
            assert_eq!(qa.saturating_mul(qb), qb.saturating_mul(qa));
            // Sign law (away from rounding-to-zero).
            let p = qa.saturating_mul(qb).to_f32();
            if (a * b).abs() > 4.0 * lsb {
                assert_eq!(
                    p.signum(),
                    (a * b).signum(),
                    "sign({a} * {b})"
                );
            }
            // widen().narrow() is identity on the 16-bit lattice.
            assert_eq!(qa.widen().narrow(), qa);
        }
    }

    /// LUT activations are monotone non-decreasing — required for the
    /// hardware sigmoid/tanh to preserve gate ordering.
    #[test]
    fn luts_are_monotone() {
        for lut in [ActLut::sigmoid(), ActLut::tanh()] {
            let mut prev = i16::MIN;
            let mut x = -9.0f32;
            while x < 9.0 {
                let y = lut.eval(Fx16::from_f32(x)).0;
                assert!(y >= prev, "LUT must be monotone at x={x}");
                prev = y;
                x += 0.01;
            }
        }
    }
}
