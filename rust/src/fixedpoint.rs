//! Parametric fixed-point substrate (paper Sec. IV-A/V-B; precision as a
//! co-design axis per Fan et al., arXiv:2105.09163, and VIBNN).
//!
//! The accelerator quantises weights and activations to a narrow fixed
//! point and keeps the LSTM cell state `c` in a widened path ("16-bit
//! representation, except c which is represented in 32-bit"). The paper's
//! reference instance is Q6.10 for the 16-bit path (range [-32, 32), LSB
//! 2^-10 ≈ 1e-3 — comfortably covering z-normalised ECG and gate
//! pre-activations) and Q12.20 for the 32-bit cell path; this module
//! generalises that pair into a runtime [`QFormat`] descriptor so the DSE
//! can trade precision for DSP/BRAM cost and throughput
//! (`docs/quantization.md`).
//!
//! Layering:
//!
//! * [`Fx16`] / [`Fx32`] — raw storage (an `i16` / `i32` lattice point).
//!   Their inherent methods are the frozen **Q6.10 legacy ops**: they are
//!   kept bit-for-bit as the pre-refactor implementation and serve as the
//!   regression oracle the parametric path is property-tested against.
//! * [`QFormat`] — one format: total bits (≤ 16 on the activation path,
//!   32 on the cell path) and fractional bits. Owns quantise /
//!   dequantise / saturating arithmetic at that format.
//! * [`QuantSpec`] — an engine's format pair `{act, cell}` plus the
//!   widen/narrow/cell arithmetic between them.
//! * [`Precision`] — a whole design's quantisation: a default spec with
//!   per-LSTM-layer overrides.
//!
//! Activation functions are BRAM-style lookup tables over a precomputed
//! input range, exactly like the hardware (Sec. III-A); tables are built
//! per format ([`ActLut::with_format`]).
//!
//! All arithmetic rounds to nearest and saturates (no wrap-around),
//! matching DSP-block behaviour with saturation logic.
//!
//! **Bit-exactness contract:** every parametric operation at
//! `QFormat::Q16_ACT` / `QuantSpec::q16()` is bit-identical to the
//! corresponding legacy Q6.10 op (tested below at the op level; the
//! engine and accelerator levels pin the same contract in
//! `fpga::engine` / `fpga::accel`).

/// Fractional bits of the 16-bit path (Q6.10).
pub const FRAC16: i32 = 10;
/// Fractional bits of the 32-bit cell path (Q12.20).
pub const FRAC32: i32 = 20;

/// 16-bit fixed-point value, Q6.10.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Fx16(pub i16);

/// 32-bit fixed-point value, Q12.20 (the cell-state path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Fx32(pub i32);

impl Fx16 {
    pub const ZERO: Fx16 = Fx16(0);
    pub const ONE: Fx16 = Fx16(1 << FRAC16);

    /// Quantise an f32 (round-to-nearest, saturate).
    #[inline]
    pub fn from_f32(v: f32) -> Self {
        let scaled = (v as f64 * (1i64 << FRAC16) as f64).round();
        Fx16(scaled.clamp(i16::MIN as f64, i16::MAX as f64) as i16)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / (1 << FRAC16) as f32
    }

    #[inline]
    pub fn saturating_add(self, rhs: Fx16) -> Fx16 {
        Fx16(self.0.saturating_add(rhs.0))
    }

    /// Fixed-point multiply: (a*b) >> FRAC16 with rounding and saturation —
    /// one DSP48 multiplier in the hardware.
    #[inline]
    pub fn saturating_mul(self, rhs: Fx16) -> Fx16 {
        let prod = self.0 as i32 * rhs.0 as i32;
        let rounded = (prod + (1 << (FRAC16 - 1))) >> FRAC16;
        Fx16(rounded.clamp(i16::MIN as i32, i16::MAX as i32) as i16)
    }

    /// Widen to the 32-bit cell path.
    #[inline]
    pub fn widen(self) -> Fx32 {
        Fx32((self.0 as i32) << (FRAC32 - FRAC16))
    }
}

impl Fx32 {
    pub const ZERO: Fx32 = Fx32(0);

    #[inline]
    pub fn from_f32(v: f32) -> Self {
        let scaled = (v as f64 * (1i64 << FRAC32) as f64).round();
        Fx32(scaled.clamp(i32::MIN as f64, i32::MAX as f64) as i32)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / (1u32 << FRAC32) as f32
    }

    #[inline]
    pub fn saturating_add(self, rhs: Fx32) -> Fx32 {
        Fx32(self.0.saturating_add(rhs.0))
    }

    /// Multiply two 16-bit operands into the 32-bit path (f_t * c_{t-1}
    /// uses two cascaded DSPs in the paper — 16x32 -> 32).
    #[inline]
    pub fn mul_fx16(self, rhs: Fx16) -> Fx32 {
        let prod = self.0 as i64 * rhs.0 as i64;
        let rounded = (prod + (1 << (FRAC16 - 1))) >> FRAC16;
        Fx32(rounded.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
    }

    /// Narrow back to the 16-bit path (saturating).
    #[inline]
    pub fn narrow(self) -> Fx16 {
        let shifted =
            (self.0 + (1 << (FRAC32 - FRAC16 - 1))) >> (FRAC32 - FRAC16);
        Fx16(shifted.clamp(i16::MIN as i32, i16::MAX as i32) as i16)
    }
}

// ---------------------------------------------------------------------------
// Parametric quantisation descriptors.
// ---------------------------------------------------------------------------

/// One fixed-point format: `total_bits` two's-complement bits with
/// `frac_bits` of them fractional (Q`{total-frac}`.`{frac}` in Q
/// notation). Activation/weight formats use ≤ 16 bits and are stored in
/// [`Fx16`]; the widened cell format uses 32 bits in [`Fx32`]. Narrow
/// formats keep the 16-bit container — the hardware packs them, the
/// simulator only narrows the *rails* — so the resource/latency models,
/// not the container, carry the bitwidth cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QFormat {
    pub total_bits: u32,
    pub frac_bits: u32,
}

impl QFormat {
    /// The paper's 16-bit activation format, Q6.10.
    pub const Q16_ACT: QFormat = QFormat::new(16, FRAC16 as u32);
    /// 12-bit activation format, Q4.8 (range ±8, LSB 2^-8).
    pub const Q12_ACT: QFormat = QFormat::new(12, 8);
    /// 8-bit activation format, Q3.5 (range ±4, LSB 2^-5).
    pub const Q8_ACT: QFormat = QFormat::new(8, 5);
    /// The paper's 32-bit cell format, Q12.20.
    pub const Q32_CELL: QFormat = QFormat::new(32, FRAC32 as u32);

    pub const fn new(total_bits: u32, frac_bits: u32) -> Self {
        assert!(total_bits >= 2 && total_bits <= 32);
        assert!(frac_bits >= 1 && frac_bits < total_bits);
        Self { total_bits, frac_bits }
    }

    /// Largest representable raw value.
    #[inline]
    pub fn max_raw(self) -> i32 {
        if self.total_bits >= 32 {
            i32::MAX
        } else {
            (1i32 << (self.total_bits - 1)) - 1
        }
    }

    /// Smallest representable raw value.
    #[inline]
    pub fn min_raw(self) -> i32 {
        if self.total_bits >= 32 {
            i32::MIN
        } else {
            -(1i32 << (self.total_bits - 1))
        }
    }

    /// One least-significant bit in real units.
    #[inline]
    pub fn lsb(self) -> f32 {
        1.0 / (1i64 << self.frac_bits) as f32
    }

    /// Largest representable real value.
    #[inline]
    pub fn max_value(self) -> f32 {
        self.max_raw() as f32 * self.lsb()
    }

    /// MACs one DSP48 slice performs per cycle at this operand width:
    /// two ≤ 8-bit multiplies pack into one 25x18 slice (the INT8
    /// packing the companion accelerator exploits), wider operands use
    /// a full slice each.
    #[inline]
    pub fn macs_per_dsp(self) -> u64 {
        if self.total_bits <= 8 {
            2
        } else {
            1
        }
    }

    /// Quantise an f32 (round-to-nearest, saturate at the format rails).
    /// At `Q16_ACT` this is bit-identical to [`Fx16::from_f32`].
    #[inline]
    pub fn quantize(self, v: f32) -> Fx16 {
        debug_assert!(self.total_bits <= 16, "activation-path format");
        let scaled = (v as f64 * (1i64 << self.frac_bits) as f64).round();
        Fx16(scaled.clamp(self.min_raw() as f64, self.max_raw() as f64)
            as i16)
    }

    /// Quantise onto the (32-bit container) cell lattice.
    #[inline]
    pub fn quantize_cell(self, v: f32) -> Fx32 {
        let scaled = (v as f64 * (1i64 << self.frac_bits) as f64).round();
        Fx32(scaled.clamp(self.min_raw() as f64, self.max_raw() as f64)
            as i32)
    }

    #[inline]
    pub fn dequantize(self, v: Fx16) -> f32 {
        v.0 as f32 / (1i64 << self.frac_bits) as f32
    }

    #[inline]
    pub fn dequantize_cell(self, v: Fx32) -> f32 {
        v.0 as f32 / (1i64 << self.frac_bits) as f32
    }

    /// Saturating add at this format's rails.
    #[inline]
    pub fn sat_add(self, a: Fx16, b: Fx16) -> Fx16 {
        let s = a.0 as i32 + b.0 as i32;
        Fx16(s.clamp(self.min_raw(), self.max_raw()) as i16)
    }

    /// Fixed-point multiply at this format: `(a*b) >> frac` with
    /// round-to-nearest and saturation — one DSP multiplier.
    #[inline]
    pub fn sat_mul(self, a: Fx16, b: Fx16) -> Fx16 {
        let prod = a.0 as i32 * b.0 as i32;
        let rounded = (prod + (1 << (self.frac_bits - 1))) >> self.frac_bits;
        Fx16(rounded.clamp(self.min_raw(), self.max_raw()) as i16)
    }

    /// Re-express a value quantised in `from` on this format's lattice
    /// (exact when gaining fractional bits, round-to-nearest when
    /// losing them; saturates at this format's rails). Identity when
    /// the formats match — the inter-layer buses of a uniform design
    /// never touch the data.
    #[inline]
    pub fn requantize_from(self, v: Fx16, from: QFormat) -> Fx16 {
        if self == from {
            return v;
        }
        let raw = if self.frac_bits >= from.frac_bits {
            (v.0 as i32) << (self.frac_bits - from.frac_bits)
        } else {
            let shift = from.frac_bits - self.frac_bits;
            ((v.0 as i32) + (1 << (shift - 1))) >> shift
        };
        Fx16(raw.clamp(self.min_raw(), self.max_raw()) as i16)
    }

    /// Short name used by the CLI / lookup-table columns: the preset
    /// names `q8` / `q12` / `q16`, or `q<total>f<frac>` otherwise.
    pub fn name(self) -> String {
        match self {
            QFormat::Q16_ACT => "q16".into(),
            QFormat::Q12_ACT => "q12".into(),
            QFormat::Q8_ACT => "q8".into(),
            _ => format!("q{}f{}", self.total_bits, self.frac_bits),
        }
    }
}

/// An engine's quantisation: the activation/weight format and the
/// widened cell format, plus the arithmetic that crosses between them
/// (the `f_t * c_{t-1}` tail of the LSTM engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QuantSpec {
    pub act: QFormat,
    pub cell: QFormat,
}

impl QuantSpec {
    pub const fn new(act: QFormat, cell: QFormat) -> Self {
        assert!(cell.frac_bits > act.frac_bits, "cell path must widen");
        Self { act, cell }
    }

    /// The paper's reference pair: Q6.10 activations, Q12.20 cell.
    pub const fn q16() -> Self {
        Self::new(QFormat::Q16_ACT, QFormat::Q32_CELL)
    }

    /// 12-bit activations (Q4.8), cell widened to Q(32,16).
    pub const fn q12() -> Self {
        Self::new(QFormat::Q12_ACT, QFormat::new(32, 16))
    }

    /// 8-bit activations (Q3.5), cell widened to Q(32,10).
    pub const fn q8() -> Self {
        Self::new(QFormat::Q8_ACT, QFormat::new(32, 10))
    }

    /// Parse a preset name (`q8` / `q12` / `q16`, bare `8|12|16` also
    /// accepted).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim() {
            "q16" | "16" => Ok(Self::q16()),
            "q12" | "12" => Ok(Self::q12()),
            "q8" | "8" => Ok(Self::q8()),
            other => Err(format!(
                "unknown precision {other:?} (q8 | q12 | q16)"
            )),
        }
    }

    pub fn name(&self) -> String {
        self.act.name()
    }

    /// Shift between the cell and activation lattices.
    #[inline]
    fn widen_shift(&self) -> u32 {
        self.cell.frac_bits - self.act.frac_bits
    }

    /// Widen an activation-path value onto the cell lattice (exact).
    /// At `q16` bit-identical to [`Fx16::widen`].
    #[inline]
    pub fn widen(&self, a: Fx16) -> Fx32 {
        Fx32((a.0 as i32) << self.widen_shift())
    }

    /// Narrow a cell value back to the activation path (round, saturate
    /// at the activation rails). At `q16` bit-identical to
    /// [`Fx32::narrow`].
    #[inline]
    pub fn narrow(&self, c: Fx32) -> Fx16 {
        let shift = self.widen_shift();
        let shifted = (c.0 + (1 << (shift - 1))) >> shift;
        Fx16(shifted.clamp(self.act.min_raw(), self.act.max_raw()) as i16)
    }

    /// `c * a` on the widened path (the 2-cascaded-DSP 16x32 multiply of
    /// the paper). At `q16` bit-identical to [`Fx32::mul_fx16`].
    #[inline]
    pub fn cell_mul_act(&self, c: Fx32, a: Fx16) -> Fx32 {
        let prod = c.0 as i64 * a.0 as i64;
        let rounded =
            (prod + (1 << (self.act.frac_bits - 1))) >> self.act.frac_bits;
        Fx32(
            rounded.clamp(self.cell.min_raw() as i64, self.cell.max_raw() as i64)
                as i32,
        )
    }

    /// Saturating add on the cell path. At `q16` (32-bit cell rails)
    /// bit-identical to [`Fx32::saturating_add`].
    #[inline]
    pub fn cell_add(&self, a: Fx32, b: Fx32) -> Fx32 {
        let s = a.0 as i64 + b.0 as i64;
        Fx32(
            s.clamp(self.cell.min_raw() as i64, self.cell.max_raw() as i64)
                as i32,
        )
    }
}

/// A whole design's quantisation: one default [`QuantSpec`] plus
/// per-LSTM-layer overrides — the paper's per-layer `B` pattern extended
/// to the precision axis. The final dense head runs at the default
/// activation format.
#[derive(Debug, Clone, PartialEq)]
pub struct Precision {
    pub default: QuantSpec,
    /// `(lstm_layer_index, spec)` overrides, later entries win.
    pub overrides: Vec<(usize, QuantSpec)>,
}

impl Precision {
    pub fn uniform(spec: QuantSpec) -> Self {
        Self { default: spec, overrides: Vec::new() }
    }

    pub fn q16() -> Self {
        Self::uniform(QuantSpec::q16())
    }

    pub fn q12() -> Self {
        Self::uniform(QuantSpec::q12())
    }

    pub fn q8() -> Self {
        Self::uniform(QuantSpec::q8())
    }

    /// Builder-style per-layer override.
    pub fn with_layer(mut self, layer: usize, spec: QuantSpec) -> Self {
        self.overrides.push((layer, spec));
        self
    }

    /// The spec LSTM layer `l` runs at.
    pub fn spec_for(&self, layer: usize) -> QuantSpec {
        self.overrides
            .iter()
            .rev()
            .find(|&&(l, _)| l == layer)
            .map(|&(_, s)| s)
            .unwrap_or(self.default)
    }

    /// Whether this is exactly the paper's uniform Q6.10/Q12.20 design
    /// (the bit-exactness baseline).
    pub fn is_q16(&self) -> bool {
        self.default == QuantSpec::q16()
            && self.overrides.iter().all(|&(_, s)| s == QuantSpec::q16())
    }

    /// `q8` / `q12` / `q16`, with `+l<i>=<fmt>` suffixes for overrides
    /// (e.g. `q8+l0=q16`). The name is canonical: overrides that merely
    /// restate the default are dropped, so a semantically-uniform
    /// precision (e.g. parsed from `q16,l0=q16`) names itself exactly
    /// like the plain preset — the lookup table's quantised-accuracy
    /// columns and their q16 float fallback key off this name.
    pub fn name(&self) -> String {
        let mut out = self.default.name();
        for &(l, s) in &self.overrides {
            if s != self.default {
                out.push_str(&format!("+l{l}={}", s.name()));
            }
        }
        out
    }

    /// Parse `q8` / `q12` / `q16` with optional per-layer overrides:
    /// `q8,l0=q16,l2=q12`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut parts = s.split(',');
        let default = QuantSpec::parse(
            parts.next().ok_or_else(|| "empty precision".to_string())?,
        )?;
        let mut prec = Precision::uniform(default);
        for part in parts {
            let (layer, fmt) = part
                .trim()
                .strip_prefix('l')
                .and_then(|p| p.split_once('='))
                .ok_or_else(|| {
                    format!("bad per-layer override {part:?} (want l<i>=q8)")
                })?;
            let l: usize = layer
                .parse()
                .map_err(|_| format!("bad layer index {layer:?}"))?;
            prec = prec.with_layer(l, QuantSpec::parse(fmt)?);
        }
        Ok(prec)
    }
}

/// 16-bit MAC accumulator for MVM engines: products are accumulated in a
/// wide register (as DSP48 cascades do) and narrowed once at the end —
/// avoids per-term quantisation error.
#[derive(Debug, Clone, Copy, Default)]
pub struct MacAcc(i64);

impl MacAcc {
    #[inline]
    pub fn new() -> Self {
        MacAcc(0)
    }

    #[inline]
    pub fn mac(&mut self, a: Fx16, b: Fx16) {
        self.mac_raw(a.0, b.0); // Q(2*frac)
    }

    /// MAC of raw lattice points — the kernels' entry: packed `i8`/`i16`
    /// weight planes widen to `i16` in-register and land here, so the
    /// accumulated bits are identical to the unpacked [`MacAcc::mac`].
    #[inline]
    pub fn mac_raw(&mut self, a: i16, b: i16) {
        self.0 += a as i64 * b as i64;
    }

    /// Finish: add bias (Q10) and narrow to Fx16 with rounding/saturation
    /// — the frozen Q6.10 legacy op ([`MacAcc::finish_fmt`] generalises
    /// it; bit-identical at `QFormat::Q16_ACT`, property-tested below).
    #[inline]
    pub fn finish(self, bias: Fx16) -> Fx16 {
        let with_bias = self.0 + ((bias.0 as i64) << FRAC16);
        let rounded = (with_bias + (1 << (FRAC16 - 1))) >> FRAC16;
        Fx16(rounded.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
    }

    /// Format-parametric finish: operands and bias are quantised in
    /// `fmt` (so the accumulator holds Q`2*frac` products), the result
    /// is rounded back to `fmt` and saturated at its rails.
    #[inline]
    pub fn finish_fmt(self, bias: Fx16, fmt: QFormat) -> Fx16 {
        let with_bias = self.0 + ((bias.0 as i64) << fmt.frac_bits);
        let rounded =
            (with_bias + (1 << (fmt.frac_bits - 1))) >> fmt.frac_bits;
        Fx16(
            rounded.clamp(fmt.min_raw() as i64, fmt.max_raw() as i64)
                as i16,
        )
    }
}

// ---------------------------------------------------------------------------
// BRAM-style activation LUTs (Sec. III-A): sigmoid/tanh precomputed over a
// fixed input range, indexed by the upper bits of the fixed-point input.
// ---------------------------------------------------------------------------

/// Lookup-table activation over [-RANGE, RANGE] with 2^BITS entries.
pub struct ActLut {
    table: Vec<Fx16>,
    /// Input clamp range in fixed-point raw units.
    lo_raw: i32,
    hi_raw: i32,
    shift: i32,
}

/// LUT input range: |x| <= 8 saturates both sigmoid and tanh to <1 LSB of
/// the 16-bit output. Formats whose rails sit below ±8 clamp the table
/// to their representable range instead.
pub const LUT_RANGE: f32 = 8.0;
/// log2(max entries): 1024-entry tables fit one BRAM18 each at 16-bit
/// width. Narrow formats whose input span is smaller use one bucket per
/// raw unit (an exact, smaller table).
pub const LUT_BITS: u32 = 10;

impl ActLut {
    /// Q6.10 table — the legacy constructor, bit-identical to
    /// `with_format(f, QFormat::Q16_ACT)`.
    pub fn new(f: impl Fn(f64) -> f64) -> Self {
        Self::with_format(f, QFormat::Q16_ACT)
    }

    /// Build the table over `fmt`'s representation of [-LUT_RANGE,
    /// LUT_RANGE] (clamped to the format rails), with at most
    /// `2^LUT_BITS` buckets; inputs and outputs are both quantised in
    /// `fmt`. Each bucket is evaluated at its raw midpoint.
    pub fn with_format(f: impl Fn(f64) -> f64, fmt: QFormat) -> Self {
        let lo_raw = fmt.quantize(-LUT_RANGE).0 as i32;
        let hi_raw = fmt.quantize(LUT_RANGE).0 as i32;
        let span = (hi_raw - lo_raw) as i64;
        debug_assert!(span > 0, "degenerate LUT span");
        // Bucket width: the smallest power of two keeping the table
        // within 2^LUT_BITS entries (shift 4 at Q6.10: span 2^14 over
        // 2^10 entries; shift 0 — exact per-raw-unit buckets — for
        // narrow formats whose whole span fits).
        let max_entries = 1i64 << LUT_BITS;
        let mut shift = 0i32;
        while (span >> shift) > max_entries {
            shift += 1;
        }
        let entries = ((span + (1i64 << shift) - 1) >> shift) as usize;
        let mut table = Vec::with_capacity(entries);
        for i in 0..entries {
            let raw_mid = lo_raw as i64
                + ((i as i64) << shift)
                + ((1i64 << shift) >> 1);
            let x = raw_mid as f64 / (1i64 << fmt.frac_bits) as f64;
            table.push(fmt.quantize(f(x) as f32));
        }
        Self { table, lo_raw, hi_raw, shift }
    }

    pub fn sigmoid() -> Self {
        Self::new(|x| 1.0 / (1.0 + (-x).exp()))
    }

    pub fn tanh() -> Self {
        Self::new(|x| x.tanh())
    }

    pub fn sigmoid_fmt(fmt: QFormat) -> Self {
        Self::with_format(|x| 1.0 / (1.0 + (-x).exp()), fmt)
    }

    pub fn tanh_fmt(fmt: QFormat) -> Self {
        Self::with_format(|x| x.tanh(), fmt)
    }

    /// One BRAM read: clamp, index by upper bits, return table entry.
    #[inline]
    pub fn eval(&self, x: Fx16) -> Fx16 {
        let raw = (x.0 as i32).clamp(self.lo_raw, self.hi_raw - 1);
        let idx = ((raw - self.lo_raw) >> self.shift) as usize;
        self.table[idx]
    }

    pub fn entries(&self) -> usize {
        self.table.len()
    }
}

/// Quantise an f32 slice to Fx16 (legacy Q6.10).
pub fn quantize(v: &[f32]) -> Vec<Fx16> {
    v.iter().map(|&x| Fx16::from_f32(x)).collect()
}

/// Dequantise back to f32 (for metric evaluation of the quantised model).
pub fn dequantize(v: &[Fx16]) -> Vec<f32> {
    v.iter().map(|x| x.to_f32()).collect()
}

/// Quantise an f32 slice in an explicit format.
pub fn quantize_fmt(v: &[f32], fmt: QFormat) -> Vec<Fx16> {
    v.iter().map(|&x| fmt.quantize(x)).collect()
}

/// Dequantise a slice quantised in `fmt`.
pub fn dequantize_fmt(v: &[Fx16], fmt: QFormat) -> Vec<f32> {
    v.iter().map(|&x| fmt.dequantize(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_precision() {
        for &v in &[0.0f32, 1.0, -1.0, 0.123, -3.875, 7.5, -20.25] {
            let q = Fx16::from_f32(v);
            assert!(
                (q.to_f32() - v).abs() <= 0.5 / (1 << FRAC16) as f32 + 1e-6,
                "v={v} q={}",
                q.to_f32()
            );
        }
    }

    #[test]
    fn saturation_at_range_edges() {
        assert_eq!(Fx16::from_f32(1e9).0, i16::MAX);
        assert_eq!(Fx16::from_f32(-1e9).0, i16::MIN);
        let big = Fx16::from_f32(31.0);
        assert_eq!(big.saturating_add(big).0, i16::MAX);
    }

    #[test]
    fn mul_matches_float() {
        let a = Fx16::from_f32(1.5);
        let b = Fx16::from_f32(-2.25);
        let p = a.saturating_mul(b).to_f32();
        assert!((p - (-3.375)).abs() < 2.0 / (1 << FRAC16) as f32);
    }

    #[test]
    fn widen_narrow_roundtrip() {
        let a = Fx16::from_f32(2.375);
        assert_eq!(a.widen().narrow(), a);
        let c = Fx32::from_f32(-1.8125);
        assert!((c.narrow().to_f32() - -1.8125).abs() < 1e-3);
    }

    #[test]
    fn fx32_mul_fx16() {
        let c = Fx32::from_f32(0.5);
        let f = Fx16::from_f32(0.5);
        assert!((c.mul_fx16(f).to_f32() - 0.25).abs() < 1e-5);
    }

    #[test]
    fn mac_accumulator_exactness() {
        // MAC of quantised values must equal exact integer math.
        let xs = [0.5f32, -0.25, 1.75, 0.125];
        let ws = [1.0f32, 0.5, -0.5, 2.0];
        let mut acc = MacAcc::new();
        for (&x, &w) in xs.iter().zip(ws.iter()) {
            acc.mac(Fx16::from_f32(x), Fx16::from_f32(w));
        }
        let got = acc.finish(Fx16::from_f32(0.25)).to_f32();
        let want: f32 =
            xs.iter().zip(ws.iter()).map(|(x, w)| x * w).sum::<f32>() + 0.25;
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    #[test]
    fn sigmoid_lut_accuracy() {
        let lut = ActLut::sigmoid();
        for i in -800..800 {
            let x = i as f32 * 0.01;
            let got = lut.eval(Fx16::from_f32(x)).to_f32();
            let want = 1.0 / (1.0 + (-x).exp());
            assert!(
                (got - want).abs() < 0.01,
                "sigmoid({x}) LUT={got} exact={want}"
            );
        }
    }

    #[test]
    fn tanh_lut_accuracy() {
        let lut = ActLut::tanh();
        for i in -800..800 {
            let x = i as f32 * 0.01;
            let got = lut.eval(Fx16::from_f32(x)).to_f32();
            assert!(
                (got - x.tanh()).abs() < 0.02,
                "tanh({x}) LUT={got} exact={}",
                x.tanh()
            );
        }
    }

    #[test]
    fn lut_saturates_out_of_range() {
        let lut = ActLut::sigmoid();
        assert!((lut.eval(Fx16::from_f32(20.0)).to_f32() - 1.0).abs() < 0.01);
        assert!(lut.eval(Fx16::from_f32(-20.0)).to_f32() < 0.01);
        assert_eq!(lut.entries(), 1 << LUT_BITS);
    }

    #[test]
    fn quantize_dequantize_slice() {
        let v = vec![0.1f32, -0.9, 2.5];
        let d = dequantize(&quantize(&v));
        for (a, b) in v.iter().zip(d.iter()) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    /// Property sweep: quantisation error bound, add commutativity,
    /// multiply sign law and widen/narrow idempotence over random values.
    #[test]
    fn property_sweep_random_values() {
        use crate::rng::Rng;
        let mut rng = Rng::new(77);
        let lsb = 1.0 / (1 << FRAC16) as f32;
        for _ in 0..2000 {
            let a = rng.uniform_in(-20.0, 20.0) as f32;
            let b = rng.uniform_in(-20.0, 20.0) as f32;
            let qa = Fx16::from_f32(a);
            let qb = Fx16::from_f32(b);
            // Rounding bound.
            assert!((qa.to_f32() - a).abs() <= 0.5 * lsb + 1e-6);
            // Commutativity.
            assert_eq!(qa.saturating_add(qb), qb.saturating_add(qa));
            assert_eq!(qa.saturating_mul(qb), qb.saturating_mul(qa));
            // Sign law (away from rounding-to-zero).
            let p = qa.saturating_mul(qb).to_f32();
            if (a * b).abs() > 4.0 * lsb {
                assert_eq!(
                    p.signum(),
                    (a * b).signum(),
                    "sign({a} * {b})"
                );
            }
            // widen().narrow() is identity on the 16-bit lattice.
            assert_eq!(qa.widen().narrow(), qa);
        }
    }

    /// LUT activations are monotone non-decreasing — required for the
    /// hardware sigmoid/tanh to preserve gate ordering.
    #[test]
    fn luts_are_monotone() {
        for lut in [ActLut::sigmoid(), ActLut::tanh()] {
            let mut prev = i16::MIN;
            let mut x = -9.0f32;
            while x < 9.0 {
                let y = lut.eval(Fx16::from_f32(x)).0;
                assert!(y >= prev, "LUT must be monotone at x={x}");
                prev = y;
                x += 0.01;
            }
        }
    }

    // -----------------------------------------------------------------
    // Parametric substrate: Q6.10 bit-exactness oracle.
    //
    // The inherent `Fx16` / `Fx32` / `MacAcc::finish` methods above are
    // the frozen pre-refactor implementation; every parametric op at
    // `QFormat::Q16_ACT` / `QuantSpec::q16()` must reproduce them
    // bit-for-bit (the refactor's regression contract).
    // -----------------------------------------------------------------

    #[test]
    fn q16_ops_bit_identical_to_legacy() {
        use crate::rng::Rng;
        let fmt = QFormat::Q16_ACT;
        let spec = QuantSpec::q16();
        let mut rng = Rng::new(123);
        for _ in 0..4000 {
            // Values deliberately past the rails to exercise saturation.
            let a = rng.uniform_in(-80.0, 80.0) as f32;
            let b = rng.uniform_in(-80.0, 80.0) as f32;
            assert_eq!(fmt.quantize(a).0, Fx16::from_f32(a).0, "quantize {a}");
            let qa = Fx16::from_f32(a);
            let qb = Fx16::from_f32(b);
            assert_eq!(fmt.dequantize(qa), qa.to_f32());
            assert_eq!(fmt.sat_add(qa, qb), qa.saturating_add(qb));
            assert_eq!(fmt.sat_mul(qa, qb), qa.saturating_mul(qb));
            assert_eq!(spec.widen(qa), qa.widen());
            let c = Fx32::from_f32(rng.uniform_in(-2000.0, 2000.0) as f32);
            assert_eq!(spec.narrow(c), c.narrow());
            assert_eq!(spec.cell_mul_act(c, qa), c.mul_fx16(qa));
            let c2 = Fx32::from_f32(rng.uniform_in(-2000.0, 2000.0) as f32);
            assert_eq!(spec.cell_add(c, c2), c.saturating_add(c2));
            // Requantize q16 -> q16 is the identity.
            assert_eq!(fmt.requantize_from(qa, fmt), qa);
        }
    }

    #[test]
    fn q16_mac_finish_bit_identical_to_legacy() {
        use crate::rng::Rng;
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            let mut acc_a = MacAcc::new();
            let mut acc_b = MacAcc::new();
            for _ in 0..1 + rng.below(24) {
                let x = Fx16::from_f32(rng.uniform_in(-8.0, 8.0) as f32);
                let w = Fx16::from_f32(rng.uniform_in(-8.0, 8.0) as f32);
                acc_a.mac(x, w);
                acc_b.mac(x, w);
            }
            let bias = Fx16::from_f32(rng.uniform_in(-4.0, 4.0) as f32);
            assert_eq!(
                acc_a.finish(bias),
                acc_b.finish_fmt(bias, QFormat::Q16_ACT)
            );
        }
    }

    #[test]
    fn q16_luts_bit_identical_to_legacy_tables() {
        // `ActLut::new` now routes through `with_format`; pin the table
        // geometry so a drift in the generic construction is caught.
        let lut = ActLut::sigmoid_fmt(QFormat::Q16_ACT);
        assert_eq!(lut.entries(), 1 << LUT_BITS);
        assert_eq!(lut.shift, 4, "Q6.10 over ±8 is 16 raw units/bucket");
        assert_eq!(lut.lo_raw, -(8 << FRAC16));
        assert_eq!(lut.hi_raw, 8 << FRAC16);
        // Midpoint rule: bucket i evaluated at lo + 16 i + 8.
        let i = 137usize;
        let x = (lut.lo_raw as i64 + 16 * i as i64 + 8) as f64
            / (1 << FRAC16) as f64;
        let want = Fx16::from_f32((1.0 / (1.0 + (-x).exp())) as f32);
        assert_eq!(lut.table[i], want);
    }

    // -----------------------------------------------------------------
    // Per-format edge cases (ISSUE 4 satellite): saturation rails,
    // ±0.5 LSB rounding, quantisation error bounds.
    // -----------------------------------------------------------------

    fn act_formats() -> [QFormat; 3] {
        [QFormat::Q8_ACT, QFormat::Q12_ACT, QFormat::Q16_ACT]
    }

    #[test]
    fn format_rails_saturate_and_roundtrip() {
        for fmt in act_formats() {
            let max = fmt.max_value();
            // Far past the rails: clamps exactly to them.
            assert_eq!(fmt.quantize(1e9).0 as i32, fmt.max_raw());
            assert_eq!(fmt.quantize(-1e9).0 as i32, fmt.min_raw());
            // The rails survive a dequantize -> quantize round trip.
            let hi = fmt.quantize(max);
            assert_eq!(fmt.quantize(fmt.dequantize(hi)), hi);
            // Additive saturation pins at the rail instead of wrapping.
            let near = fmt.quantize(max * 0.75);
            assert_eq!(fmt.sat_add(near, near).0 as i32, fmt.max_raw());
            let lo = Fx16(fmt.min_raw() as i16);
            assert_eq!(fmt.sat_add(lo, lo).0 as i32, fmt.min_raw());
        }
    }

    #[test]
    fn widen_narrow_roundtrips_at_saturation_rails() {
        for spec in [QuantSpec::q8(), QuantSpec::q12(), QuantSpec::q16()] {
            // widen().narrow() is the identity on the whole activation
            // lattice, rails included.
            for raw in [
                spec.act.min_raw(),
                spec.act.min_raw() + 1,
                -1,
                0,
                1,
                spec.act.max_raw() - 1,
                spec.act.max_raw(),
            ] {
                let a = Fx16(raw as i16);
                assert_eq!(
                    spec.narrow(spec.widen(a)),
                    a,
                    "{}: widen/narrow must be identity at raw {raw}",
                    spec.name()
                );
            }
            // A cell value past the activation rails narrows to the rail.
            let big = Fx32(
                (spec.act.max_raw() + 7) << (spec.cell.frac_bits
                    - spec.act.frac_bits),
            );
            assert_eq!(spec.narrow(big).0 as i32, spec.act.max_raw());
            let small = Fx32(
                (spec.act.min_raw() - 7) << (spec.cell.frac_bits
                    - spec.act.frac_bits),
            );
            assert_eq!(spec.narrow(small).0 as i32, spec.act.min_raw());
        }
    }

    #[test]
    fn rounding_at_half_lsb_ties_away_from_zero() {
        for fmt in act_formats() {
            let lsb = fmt.lsb() as f64;
            for k in [-5i32, -1, 0, 1, 5] {
                let base = k as f64 * lsb;
                // Exactly ±0.5 LSB is a tie on the scaled integer;
                // `f64::round` (the legacy Q6.10 rule too) breaks ties
                // away from zero.
                let tie = fmt.quantize((base + 0.5 * lsb) as f32);
                let want_tie = if 2 * k + 1 > 0 { k + 1 } else { k };
                assert_eq!(
                    tie.0 as i32,
                    want_tie,
                    "{}: tie at {base} + 0.5 LSB",
                    fmt.name()
                );
                // Just below the tie rounds to nearest (k).
                let down = fmt.quantize((base + 0.49 * lsb) as f32);
                assert_eq!(
                    down.0 as i32,
                    k,
                    "{}: {base} + 0.49 LSB",
                    fmt.name()
                );
                // Just above rounds to k + 1.
                let up = fmt.quantize((base + 0.51 * lsb) as f32);
                assert_eq!(
                    up.0 as i32,
                    k + 1,
                    "{}: {base} + 0.51 LSB",
                    fmt.name()
                );
            }
        }
    }

    /// Property sweep: every supported format quantises in-range values
    /// to within half an LSB, and requantisation between formats stays
    /// within the coarser format's half-LSB of the real value.
    #[test]
    fn per_format_quantization_error_bounds() {
        use crate::rng::Rng;
        let mut rng = Rng::new(31);
        for fmt in act_formats() {
            let range = fmt.max_value() * 0.95;
            for _ in 0..2000 {
                let v = rng.uniform_in(-range as f64, range as f64) as f32;
                let q = fmt.quantize(v);
                assert!(
                    (fmt.dequantize(q) - v).abs() <= 0.5 * fmt.lsb() + 1e-6,
                    "{}: quantize({v})",
                    fmt.name()
                );
            }
        }
        // Cross-format requantisation: q16 -> q8 -> value within q8's
        // half-LSB (plus the q16 residue); q8 -> q16 is exact.
        let (fine, coarse) = (QFormat::Q16_ACT, QFormat::Q8_ACT);
        for _ in 0..2000 {
            let v = rng.uniform_in(-3.5, 3.5) as f32;
            let qf = fine.quantize(v);
            let qc = coarse.requantize_from(qf, fine);
            assert!(
                (coarse.dequantize(qc) - fine.dequantize(qf)).abs()
                    <= 0.5 * coarse.lsb() + 1e-6,
                "q16 -> q8 at {v}"
            );
            let back = fine.requantize_from(qc, coarse);
            assert_eq!(
                fine.dequantize(back),
                coarse.dequantize(qc),
                "q8 -> q16 must be exact"
            );
        }
    }

    #[test]
    fn narrow_format_luts_stay_accurate_and_monotone() {
        for fmt in act_formats() {
            let sig = ActLut::sigmoid_fmt(fmt);
            let tanh = ActLut::tanh_fmt(fmt);
            assert!(sig.entries() <= 1 << LUT_BITS);
            // Tolerance: one output LSB plus the input-bucket slope.
            let tol = (2.0 * fmt.lsb() + 0.01) as f64;
            let (mut prev_s, mut prev_t) = (i16::MIN, i16::MIN);
            let mut x = -(fmt.max_value() as f64) * 0.98;
            while x < fmt.max_value() as f64 * 0.98 {
                let q = fmt.quantize(x as f32);
                let got_s = fmt.dequantize(sig.eval(q)) as f64;
                let want_s = 1.0 / (1.0 + (-x).exp());
                assert!(
                    (got_s - want_s).abs() < tol,
                    "{}: sigmoid({x}) = {got_s} vs {want_s}",
                    fmt.name()
                );
                let got_t = fmt.dequantize(tanh.eval(q)) as f64;
                assert!(
                    (got_t - x.tanh()).abs() < tol,
                    "{}: tanh({x}) = {got_t}",
                    fmt.name()
                );
                assert!(sig.eval(q).0 >= prev_s, "{}: sigmoid monotone", fmt.name());
                assert!(tanh.eval(q).0 >= prev_t, "{}: tanh monotone", fmt.name());
                prev_s = sig.eval(q).0;
                prev_t = tanh.eval(q).0;
                x += 0.01;
            }
        }
    }

    #[test]
    fn precision_presets_parse_and_name() {
        assert_eq!(QuantSpec::parse("q8").unwrap(), QuantSpec::q8());
        assert_eq!(QuantSpec::parse("16").unwrap(), QuantSpec::q16());
        assert!(QuantSpec::parse("q7").is_err());
        let p = Precision::parse("q8,l1=q16").unwrap();
        assert_eq!(p.default, QuantSpec::q8());
        assert_eq!(p.spec_for(0), QuantSpec::q8());
        assert_eq!(p.spec_for(1), QuantSpec::q16());
        assert_eq!(p.name(), "q8+l1=q16");
        assert!(!p.is_q16());
        assert!(Precision::q16().is_q16());
        // Canonical names: redundant overrides don't perturb the name,
        // so a `q16,l0=q16` precision still reads the lookup table's
        // q16 columns (float fallback included).
        let redundant = Precision::parse("q16,l0=q16").unwrap();
        assert!(redundant.is_q16());
        assert_eq!(redundant.name(), "q16");
        assert_eq!(
            Precision::parse("q8,l2=q8").unwrap().name(),
            "q8"
        );
        assert!(Precision::parse("q8,x=q16").is_err());
        // Packing: two 8-bit MACs per DSP, one otherwise.
        assert_eq!(QFormat::Q8_ACT.macs_per_dsp(), 2);
        assert_eq!(QFormat::Q12_ACT.macs_per_dsp(), 1);
        assert_eq!(QFormat::Q16_ACT.macs_per_dsp(), 1);
    }
}
