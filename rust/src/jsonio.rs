//! Minimal JSON parser + writer (no serde in this offline environment —
//! see Cargo.toml). Covers the full JSON grammar needed by the artifact
//! manifest, DSE lookup tables and experiment reports: objects, arrays,
//! strings with escapes, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (the manifest only carries
/// shapes/counts well within 2^53).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field {key:?}"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing array field {key:?}"))
    }
}

// ---------------------------------------------------------------------------
// Parsing.
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other, self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => anyhow::bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => anyhow::bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    anyhow::anyhow!("bad \\u escape")
                                })?;
                        }
                        s.push(
                            char::from_u32(code)
                                .unwrap_or(char::REPLACEMENT_CHARACTER),
                        );
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                },
                c if c < 0x20 => anyhow::bail!("raw control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            anyhow::bail!("truncated UTF-8");
                        }
                        s.push_str(
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|e| anyhow::anyhow!("{e}"))?,
                        );
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

// ---------------------------------------------------------------------------
// Writing.
// ---------------------------------------------------------------------------

pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(v) => {
            out.push('[');
            for (i, e) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(e, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(e, out);
            }
            out.push('}');
        }
    }
}

/// Convenience: build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "version": 1,
            "artifacts": [
                {"name": "a.fwd_n30", "rows": 30, "args":
                  [{"name": "xs", "shape": [30, 140, 1], "dtype": "f32"}]}
            ]
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.req_usize("version").unwrap(), 1);
        let arts = j.req_arr("artifacts").unwrap();
        assert_eq!(arts[0].req_str("name").unwrap(), "a.fwd_n30");
        let shape = arts[0].req_arr("args").unwrap()[0]
            .req_arr("shape")
            .unwrap();
        let dims: Vec<usize> =
            shape.iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![30, 140, 1]);
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3e2],"b":"x\"y\n","c":true,"d":null}"#;
        let j = parse(doc).unwrap();
        let j2 = parse(&write(&j)).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("2.5E-2").unwrap().as_f64(), Some(0.025));
    }

    #[test]
    fn unicode_and_escapes() {
        let j = parse(r#""café ↑""#).unwrap();
        assert_eq!(j.as_str(), Some("café ↑"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("{}{}").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn writer_escapes() {
        let j = obj(vec![("k", Json::Str("a\"b\\c\n".into()))]);
        assert_eq!(write(&j), r#"{"k":"a\"b\\c\n"}"#);
    }

    /// Property-style fuzz: random nested values survive a write/parse trip.
    #[test]
    fn fuzz_roundtrip() {
        use crate::rng::Rng;
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => Json::Num((rng.normal() * 100.0).round() / 4.0),
                3 => Json::Str(format!("s{}", rng.next_u64() % 1000)),
                4 => Json::Arr(
                    (0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect(),
                ),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let j = gen(&mut rng, 3);
            assert_eq!(parse(&write(&j)).unwrap(), j);
        }
    }
}
