//! The full accelerator: LSTM engines + dense engine wired into the
//! autoencoder / classifier topologies of Fig. 6, with per-layer LFSR
//! Bernoulli samplers and MC-sample aggregation — the functional
//! (fixed-point) half of the simulator.

use super::engine::{DenseEngine, LstmEngine};
use crate::config::{ArchConfig, Task, GATES};
use crate::fixedpoint::Fx16;
use crate::hwmodel::resource::{ResourceEstimate, ResourceModel, ReuseFactors};
use crate::lfsr::BernoulliSampler;
use crate::nn::model::softmax_row;
use crate::nn::Params;
use crate::uq::controller::{
    AdaptiveController, AdaptiveMcConfig, McDecision,
};

/// MC-aggregated prediction for one input beat.
#[derive(Debug, Clone)]
pub struct McOutput {
    /// Per-sample raw outputs, `[s][out_len]` row-major
    /// (AE: T reconstruction points; classifier: K probabilities).
    pub samples: Vec<f32>,
    pub s: usize,
    pub out_len: usize,
}

impl McOutput {
    /// Mean prediction over the MC samples.
    pub fn mean(&self) -> Vec<f32> {
        let mut m = vec![0f32; self.out_len];
        for si in 0..self.s {
            for i in 0..self.out_len {
                m[i] += self.samples[si * self.out_len + i];
            }
        }
        for v in m.iter_mut() {
            *v /= self.s as f32;
        }
        m
    }

    /// Per-point std over samples (epistemic spread).
    pub fn std(&self) -> Vec<f32> {
        let (mean, std) = crate::metrics::mc_mean_std(
            &self.samples,
            self.s,
            self.out_len,
        );
        let _ = mean;
        std
    }
}

/// Result of one adaptive prediction ([`Accelerator::predict_adaptive`]).
#[derive(Debug, Clone)]
pub struct AdaptiveOutcome {
    /// MC-mean output over the samples actually drawn.
    pub mean: Vec<f32>,
    /// Per-point MC std over the samples actually drawn.
    pub std: Vec<f32>,
    /// Raw samples in draw order, `[s_used][out_len]` row-major (the
    /// risk policy's epistemic decomposition needs them).
    pub samples: Vec<f32>,
    /// Samples drawn before the stopping rule fired.
    pub s_used: usize,
    pub out_len: usize,
    /// `true` if the CI rule fired before `s_max` was exhausted.
    pub converged: bool,
}

/// The synthesised design: engines, samplers, reuse factors.
pub struct Accelerator {
    pub cfg: ArchConfig,
    pub reuse: ReuseFactors,
    pub lstms: Vec<LstmEngine>,
    pub dense: DenseEngine,
    pub samplers: Vec<Option<BernoulliSampler>>,
    /// Base LFSR seed the design was "synthesised" with; the fleet's
    /// seeded prediction path derives per-(request, sample) seeds from it.
    seed: u64,
    // Scratch.
    beat_q: Vec<Fx16>,
    hid_a: Vec<Fx16>,
}

impl Accelerator {
    /// "Synthesise" the design from trained float parameters.
    pub fn new(
        cfg: &ArchConfig,
        params: &Params,
        reuse: ReuseFactors,
        seed: u64,
    ) -> Self {
        let dims = cfg.lstm_dims();
        let mut lstms = Vec::with_capacity(dims.len());
        let mut samplers = Vec::with_capacity(dims.len());
        for (l, _) in dims.iter().enumerate() {
            let (wx, wh, b) = params.lstm(l);
            lstms.push(LstmEngine::new(
                wx,
                wh,
                b,
                reuse.rx,
                reuse.rh,
                cfg.bayes[l],
            ));
            samplers.push(if cfg.bayes[l] {
                Some(BernoulliSampler::new(seed ^ (l as u64 + 1) * 0x9E37))
            } else {
                None
            });
        }
        let (w, b) = params.dense();
        let dense = DenseEngine::new(w, b, reuse.rd);
        let max_h = dims.iter().map(|d| d.1).max().unwrap_or(1);
        Self {
            cfg: cfg.clone(),
            reuse,
            lstms,
            dense,
            samplers,
            seed,
            beat_q: Vec::new(),
            hid_a: vec![Fx16::ZERO; max_h],
        }
    }

    /// Re-seed every Bayesian layer's LFSR bank from one sample seed —
    /// the hardware analogue of loading fresh LFSR init values over AXI
    /// before a pass. Layer salting matches [`Accelerator::new`].
    fn reseed_samplers(&mut self, sample_seed: u64) {
        for (l, slot) in self.samplers.iter_mut().enumerate() {
            if slot.is_some() {
                *slot = Some(BernoulliSampler::new(
                    sample_seed ^ (l as u64 + 1) * 0x9E37,
                ));
            }
        }
    }

    /// Pre-sample masks for one input (Fig. 4 overlap) and load the DXs.
    fn presample_masks(&mut self) {
        for (l, engine) in self.lstms.iter_mut().enumerate() {
            if let Some(sampler) = &mut self.samplers[l] {
                let mut zx = vec![0f32; GATES * engine.idim];
                let mut zh = vec![0f32; GATES * engine.hdim];
                sampler.fill(&mut zx);
                sampler.fill(&mut zh);
                engine.set_masks(&zx, &zh);
            }
        }
    }

    /// One feedforward pass of one beat (`[T]` for the univariate ECG).
    /// Returns the raw output (T reconstruction values or K probs).
    pub fn run_pass(&mut self, beat: &[f32]) -> Vec<f32> {
        let t = self.cfg.seq_len;
        debug_assert_eq!(beat.len(), t * self.cfg.input_dim);
        self.presample_masks();
        for e in self.lstms.iter_mut() {
            e.reset();
        }
        // Quantise the DMA'd input once.
        self.beat_q.clear();
        self.beat_q.extend(beat.iter().map(|&v| Fx16::from_f32(v)));

        let nl = self.cfg.nl;
        // One reusable inter-layer buffer per pass (no per-timestep
        // allocation in the hot loop — EXPERIMENTS.md §Perf).
        let max_h = self
            .lstms
            .iter()
            .map(|e| e.hdim)
            .max()
            .unwrap_or(1)
            .max(self.cfg.input_dim);
        let mut bus: Vec<Fx16> = Vec::with_capacity(max_h);
        match self.cfg.task {
            Task::Anomaly => {
                // Encoder: stream the beat through NL engines.
                for ti in 0..t {
                    bus.clear();
                    bus.push(self.beat_q[ti]);
                    for l in 0..nl {
                        let h = self.lstms[l].step(&bus);
                        bus.clear();
                        bus.extend_from_slice(h);
                    }
                }
                // Bottleneck h_T cached for T steps.
                let emb: Vec<Fx16> = self.lstms[nl - 1].hidden().to_vec();
                let mut out = Vec::with_capacity(t);
                for _ti in 0..t {
                    bus.clear();
                    bus.extend_from_slice(&emb);
                    for l in nl..2 * nl {
                        let h = self.lstms[l].step(&bus);
                        bus.clear();
                        bus.extend_from_slice(h);
                    }
                    // Temporal dense on this step's decoder output.
                    let y = self.dense.step(&bus);
                    out.push(y[0].to_f32());
                }
                out
            }
            Task::Classify => {
                for ti in 0..t {
                    bus.clear();
                    bus.push(self.beat_q[ti]);
                    for l in 0..nl {
                        let h = self.lstms[l].step(&bus);
                        bus.clear();
                        bus.extend_from_slice(h);
                    }
                }
                let logits = self.dense.step(&bus);
                // Softmax on the dequantised logits (ARM-side postprocess,
                // as in the paper's classifier head).
                let mut probs: Vec<f32> =
                    logits.iter().map(|v| v.to_f32()).collect();
                softmax_row(&mut probs);
                probs
            }
        }
    }

    /// Full Bayesian prediction: S MC passes with fresh LFSR masks
    /// (free-running sampler state — passes depend on sampler history).
    pub fn predict(&mut self, beat: &[f32], s: usize) -> McOutput {
        let out_len = self.cfg.out_len();
        let mut samples = Vec::with_capacity(s * out_len);
        for _ in 0..s {
            samples.extend(self.run_pass(beat));
        }
        let _ = &self.hid_a;
        McOutput { samples, s, out_len }
    }

    /// MC passes `start..start+count` of a request's sample schedule,
    /// with each pass's masks seeded as `mix3(design_seed, req_seed, k)`.
    /// Unlike [`Accelerator::predict`], sample `k` is a pure function of
    /// `(design_seed, req_seed, k)` — independent of sampler history — so
    /// splitting a request's S samples across fleet engines (MC-shard)
    /// reproduces exactly the sample set a single engine would compute.
    pub fn predict_seeded(
        &mut self,
        beat: &[f32],
        req_seed: u64,
        start: usize,
        count: usize,
    ) -> McOutput {
        let out_len = self.cfg.out_len();
        let mut samples = Vec::with_capacity(count * out_len);
        for k in start..start + count {
            self.reseed_samplers(crate::rng::mix3(
                self.seed,
                req_seed,
                k as u64,
            ));
            samples.extend(self.run_pass(beat));
        }
        McOutput { samples, s: count, out_len }
    }

    /// Adaptive Bayesian prediction: draw seeded MC passes incrementally
    /// and stop once the controller's confidence-interval rule fires
    /// (`docs/uncertainty.md`). Every pass goes through
    /// [`Accelerator::predict_seeded`], so sample `k` is bit-identical
    /// whether drawn here chunk-by-chunk, eagerly in one range, or on
    /// another fleet engine — and with early exit disabled
    /// (`target_ci <= 0`) the outcome reduces to exactly the fixed-S
    /// path's sample set.
    pub fn predict_adaptive(
        &mut self,
        beat: &[f32],
        req_seed: u64,
        cfg: &AdaptiveMcConfig,
    ) -> AdaptiveOutcome {
        let mut ctl = AdaptiveController::new(*cfg, self.cfg.out_len());
        let converged = loop {
            match ctl.decision() {
                McDecision::Draw { start, count } => {
                    let out =
                        self.predict_seeded(beat, req_seed, start, count);
                    ctl.push_block(start, out.samples);
                }
                McDecision::Converged => break true,
                McDecision::Exhausted => break false,
            }
        };
        let (mean, std) = ctl.acc.finalize();
        AdaptiveOutcome {
            mean,
            std,
            samples: ctl.acc.samples_ordered(),
            s_used: ctl.acc.count(),
            out_len: ctl.acc.out_len(),
            converged,
        }
    }

    /// Post-synthesis resource report (the Table III "Used" row).
    pub fn resources_synthesized(&self) -> ResourceEstimate {
        // The autoencoder's temporal dense must sustain one output per
        // pipeline timestep, so synthesis allocates ceil(F*O*T/R_d)
        // multipliers across the timestep pipeline (the paper's H*O*T/R_d
        // term); the classifier head fires once per sequence and its tiny
        // MVM can fold into fabric.
        let dense_dsps = match self.cfg.task {
            Task::Anomaly => {
                let (f, o) = self.cfg.dense_dims();
                ((f * o * self.cfg.seq_len).div_ceil(self.reuse.rd)) as u64
            }
            Task::Classify => self.dense.dsps_synthesized(),
        };
        let dsps: u64 = self
            .lstms
            .iter()
            .map(LstmEngine::dsps_synthesized)
            .sum::<u64>()
            + dense_dsps;
        // LUT/FF/BRAM from the analytic model (fabric is not re-estimated
        // by the simulator; DSPs are the contended resource).
        let analytic = ResourceModel::estimate(&self.cfg, &self.reuse);
        ResourceEstimate {
            dsps: dsps as f64,
            luts: analytic.luts,
            ffs: analytic.ffs,
            brams: analytic.brams,
        }
    }

    /// Analytic estimate for the same design (the Sec. IV-B model) —
    /// compared against `resources_synthesized` for the 98% claim.
    pub fn resources_estimated(&self) -> ResourceEstimate {
        ResourceModel::estimate(&self.cfg, &self.reuse)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{Masks, Model};
    use crate::rng::Rng;

    fn short_cfg(task: Task) -> ArchConfig {
        let mut cfg = match task {
            Task::Anomaly => ArchConfig::new(Task::Anomaly, 8, 1, "NN"),
            Task::Classify => ArchConfig::new(Task::Classify, 8, 2, "NN"),
        };
        cfg.seq_len = 24;
        cfg
    }

    #[test]
    fn classifier_probs_sum_to_one() {
        let cfg = short_cfg(Task::Classify);
        let params = Params::init(&cfg, &mut Rng::new(0));
        let mut acc =
            Accelerator::new(&cfg, &params, ReuseFactors::new(2, 1, 1), 7);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.3).sin()).collect();
        let probs = acc.run_pass(&beat);
        assert_eq!(probs.len(), 4);
        let s: f32 = probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn fixed_point_tracks_float_model() {
        // The quantised accelerator must approximate the float engine on
        // the same weights (Tables I/II premise).
        for task in [Task::Anomaly, Task::Classify] {
            let cfg = short_cfg(task);
            let mut rng = Rng::new(4);
            let model = Model::init(cfg.clone(), &mut rng);
            let mut acc = Accelerator::new(
                &cfg,
                &model.params,
                ReuseFactors::new(1, 1, 1),
                3,
            );
            let beat: Vec<f32> = (0..cfg.seq_len)
                .map(|i| (i as f32 * 0.37).sin())
                .collect();
            let fx = acc.run_pass(&beat);
            let fl = model.forward(&beat, 1, &Masks::ones(&cfg, 1));
            assert_eq!(fx.len(), fl.len());
            let rmse = crate::metrics::rmse(&fx, &fl);
            assert!(
                rmse < 0.05,
                "task {task:?}: fixed-point drifted, rmse {rmse}"
            );
        }
    }

    #[test]
    fn pointwise_design_is_deterministic() {
        let cfg = short_cfg(Task::Classify);
        let params = Params::init(&cfg, &mut Rng::new(2));
        let mut acc =
            Accelerator::new(&cfg, &params, ReuseFactors::new(1, 1, 1), 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let a = acc.run_pass(&beat);
        let b = acc.run_pass(&beat);
        assert_eq!(a, b);
    }

    #[test]
    fn bayesian_design_varies_across_mc_samples() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let mut acc =
            Accelerator::new(&cfg, &params, ReuseFactors::new(1, 1, 1), 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let out = acc.predict(&beat, 8);
        assert_eq!(out.samples.len(), 8 * 4);
        // At least two samples must differ (MCD active).
        let first = &out.samples[0..4];
        assert!(
            (1..8).any(|s| &out.samples[s * 4..s * 4 + 4] != first),
            "MC samples identical — dropout inactive?"
        );
        // Mean is still a distribution.
        let m = out.mean();
        assert!((m.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }

    /// Seeded prediction is a pure function of (design seed, request
    /// seed, sample index): shards concatenated in order must be
    /// bit-identical to one whole-range pass — the MC-shard invariant.
    #[test]
    fn seeded_shards_concatenate_to_whole() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let reuse = ReuseFactors::new(1, 1, 1);
        let mut whole = Accelerator::new(&cfg, &params, reuse, 9);
        let all = whole.predict_seeded(&beat, 77, 0, 8);

        let mut sharded = Accelerator::new(&cfg, &params, reuse, 9);
        let mut cat = Vec::new();
        for (start, count) in [(0usize, 3usize), (3, 3), (6, 2)] {
            cat.extend(sharded.predict_seeded(&beat, 77, start, count).samples);
        }
        assert_eq!(all.samples, cat, "shard union must equal whole range");

        // A different request seed must change the sample set.
        let other = sharded.predict_seeded(&beat, 78, 0, 8);
        assert_ne!(all.samples, other.samples);

        // Samples still vary across k (dropout active).
        let first = &all.samples[0..4];
        assert!((1..8).any(|s| &all.samples[s * 4..s * 4 + 4] != first));
    }

    /// Determinism invariant (ISSUE 2 acceptance): with early exit
    /// disabled the adaptive path must be *bit-identical* to the fixed-S
    /// seeded path — same samples, same reduction order.
    #[test]
    fn adaptive_with_no_early_exit_matches_fixed_path_bitwise() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let reuse = ReuseFactors::new(1, 1, 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let s_max = 10;

        // Fixed-S reference: one eager seeded range, reduced the
        // canonical way (ascending-k moment sums -> pooled mean/std).
        let mut fixed = Accelerator::new(&cfg, &params, reuse, 9);
        let whole = fixed.predict_seeded(&beat, 55, 0, s_max);
        let mut acc = crate::uq::McAccumulator::new(whole.out_len);
        acc.push_block(0, whole.samples.clone());
        let (fm, fs) = acc.finalize();

        // Adaptive with target_ci = 0: draws chunks until s_max.
        let mut adaptive = Accelerator::new(&cfg, &params, reuse, 9);
        let mc = AdaptiveMcConfig {
            s_min: 3,
            s_max,
            target_ci: 0.0,
            z: 1.96,
            chunk: 4,
        };
        let out = adaptive.predict_adaptive(&beat, 55, &mc);
        assert_eq!(out.s_used, s_max, "no early exit at target_ci = 0");
        assert!(!out.converged);
        assert_eq!(out.samples, whole.samples, "identical sample set");
        assert_eq!(out.mean, fm, "bit-identical mean");
        assert_eq!(out.std, fs, "bit-identical std");
    }

    #[test]
    fn adaptive_early_exit_saves_samples_and_stays_in_envelope() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(4));
        let mut acc = Accelerator::new(
            &cfg,
            &params,
            ReuseFactors::new(2, 1, 1),
            7,
        );
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.3).sin()).collect();
        // Probabilities live in [0, 1]: per-point std <= 0.5, so the CI
        // half-width at s_min = 4 is <= 1.96*0.5/2 < 1.0 — a target of
        // 1.0 must always converge at exactly s_min.
        let mc = AdaptiveMcConfig {
            s_min: 4,
            s_max: 32,
            target_ci: 1.0,
            z: 1.96,
            chunk: 4,
        };
        let out = acc.predict_adaptive(&beat, 3, &mc);
        assert!(out.converged);
        assert_eq!(out.s_used, 4, "easy target converges at s_min");
        assert_eq!(out.samples.len(), out.s_used * out.out_len);
        assert!((out.mean.iter().sum::<f32>() - 1.0).abs() < 1e-4);

        // An impossible target exhausts the budget instead.
        let hard = AdaptiveMcConfig { target_ci: 1e-12, ..mc };
        let out = acc.predict_adaptive(&beat, 3, &hard);
        assert!(!out.converged);
        assert_eq!(out.s_used, 32);
    }

    #[test]
    fn reuse_factors_do_not_change_numerics() {
        let cfg = short_cfg(Task::Anomaly);
        let params = Params::init(&cfg, &mut Rng::new(5));
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.15).sin()).collect();
        let mut a1 =
            Accelerator::new(&cfg, &params, ReuseFactors::new(1, 1, 1), 1);
        let mut a2 =
            Accelerator::new(&cfg, &params, ReuseFactors::new(8, 4, 2), 1);
        assert_eq!(a1.run_pass(&beat), a2.run_pass(&beat));
        // But they do change resources.
        assert!(
            a2.resources_synthesized().dsps < a1.resources_synthesized().dsps
        );
    }

    #[test]
    fn resource_model_within_2_percent_of_synthesis() {
        // The Table III claim: the analytic DSP model is >= 98% accurate
        // against the synthesised design.
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let params = Params::init(&cfg, &mut Rng::new(0));
        let acc = Accelerator::new(
            &cfg,
            &params,
            ReuseFactors::new(12, 1, 1),
            0,
        );
        let syn = acc.resources_synthesized().dsps;
        let est = acc.resources_estimated().dsps;
        let err = (syn - est).abs() / syn;
        assert!(err < 0.02, "model error {err}: syn {syn} est {est}");
    }
}
