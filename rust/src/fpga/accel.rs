//! The full accelerator: LSTM engines + dense engine wired into the
//! autoencoder / classifier topologies of Fig. 6, with per-layer LFSR
//! Bernoulli samplers and MC-sample aggregation — the functional
//! (fixed-point) half of the simulator.
//!
//! Quantisation is a constructor parameter ([`Accelerator::
//! with_precision`], `docs/quantization.md`): every LSTM layer runs at
//! its [`crate::fixedpoint::QuantSpec`] (per-layer overridable), the
//! dense head at the design default, and the inter-layer bus is
//! requantised only where adjacent layers disagree — a uniform design
//! never touches lane data between layers, so the Q6.10 instance is
//! bit-identical to the pre-refactor accelerator.

use std::sync::Arc;

use super::engine::{DenseEngine, LstmEngine};
use crate::config::{ArchConfig, Task};
use crate::fixedpoint::{Fx16, Precision, QFormat};
use crate::kernels::maskbank::MaskKey;
use crate::kernels::{self, KernelBackend, MaskBank};
use crate::hwmodel::resource::{ResourceEstimate, ResourceModel, ReuseFactors};
use crate::lfsr::BernoulliSampler;
use crate::nn::model::softmax_row;
use crate::nn::Params;
use crate::uq::controller::{
    AdaptiveController, AdaptiveMcConfig, McDecision,
};

/// MC-aggregated prediction for one input beat.
#[derive(Debug, Clone)]
pub struct McOutput {
    /// Per-sample raw outputs, `[s][out_len]` row-major
    /// (AE: T reconstruction points; classifier: K probabilities).
    pub samples: Vec<f32>,
    pub s: usize,
    pub out_len: usize,
}

impl McOutput {
    /// Per-point MC mean and std in one walk over the samples — callers
    /// needing both (every serving path) should use this rather than
    /// `mean()` + `std()`, which each walk the sample buffer.
    pub fn mean_std(&self) -> (Vec<f32>, Vec<f32>) {
        crate::metrics::mc_mean_std(&self.samples, self.s, self.out_len)
    }

    /// Mean prediction over the MC samples (single sum pass — no
    /// variance work for mean-only callers like the eval loops).
    pub fn mean(&self) -> Vec<f32> {
        let mut m = vec![0f32; self.out_len];
        for row in self.samples.chunks_exact(self.out_len) {
            for (mi, &v) in m.iter_mut().zip(row) {
                *mi += v;
            }
        }
        for v in m.iter_mut() {
            *v /= self.s as f32;
        }
        m
    }

    /// Per-point std over samples (epistemic spread).
    pub fn std(&self) -> Vec<f32> {
        self.mean_std().1
    }
}

/// Result of one adaptive prediction ([`Accelerator::predict_adaptive`]).
#[derive(Debug, Clone)]
pub struct AdaptiveOutcome {
    /// MC-mean output over the samples actually drawn.
    pub mean: Vec<f32>,
    /// Per-point MC std over the samples actually drawn.
    pub std: Vec<f32>,
    /// Raw samples in draw order, `[s_used][out_len]` row-major (the
    /// risk policy's epistemic decomposition needs them).
    pub samples: Vec<f32>,
    /// Samples drawn before the stopping rule fired.
    pub s_used: usize,
    pub out_len: usize,
    /// `true` if the CI rule fired before `s_max` was exhausted.
    pub converged: bool,
}

/// One request's shard of a blocked batch pass: `count` MC samples
/// `start..start + count` of `beat`'s schedule, mask-seeded from
/// `req_seed` exactly like [`Accelerator::predict_seeded`].
#[derive(Debug, Clone, Copy)]
pub struct BatchRequest<'a> {
    pub beat: &'a [f32],
    pub req_seed: u64,
    pub start: usize,
    pub count: usize,
}

/// Requantise a bus slice in place when adjacent layers run different
/// formats. Exact no-op (not even a copy) when the formats match, so
/// uniform designs — the Q6.10 baseline in particular — never touch
/// lane data between layers.
#[inline]
fn requantize_rows(buf: &mut [Fx16], from: QFormat, to: QFormat) {
    if from == to {
        return;
    }
    for v in buf.iter_mut() {
        *v = to.requantize_from(*v, from);
    }
}

/// Salt folded into the per-beat mask seed schedule of a streaming
/// session, so session mask streams can never collide with the
/// one-shot request space (whose `req_seed` is the fleet request id).
pub const STREAM_SALT: u64 = 0x5EED_57E4;

/// The effective request seed of beat `beat_index` of a streaming
/// session: `mix3(session_seed, beat_index, STREAM_SALT)`. Every MC
/// lane `k` of that beat then derives its mask seed exactly like
/// [`Accelerator::predict_seeded`] — `mix3(design_seed, req_seed, k)`
/// — so a session's masks are a pure function of
/// `(design, session, beat_index, k)`: chunk boundaries, MC-shard
/// splits, evictions and replays all re-derive identical bits.
pub fn stream_req_seed(session_seed: u64, beat_index: u64) -> u64 {
    crate::rng::mix3(session_seed, beat_index, STREAM_SALT)
}

/// Typed failures of the streaming prediction path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamError {
    /// Streaming decisions are classifier-only: the anomaly head
    /// replays the whole window through the decoder, which has no
    /// incremental meaning mid-stream.
    UnsupportedTask,
    /// Chunk length is not a whole number of timesteps.
    RaggedChunk { len: usize, idim: usize },
    /// The state snapshot was opened on a different design shape.
    ShapeMismatch,
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::UnsupportedTask => {
                write!(f, "streaming requires a classifier design")
            }
            StreamError::RaggedChunk { len, idim } => write!(
                f,
                "chunk of {len} values is not a whole number of \
                 {idim}-wide timesteps"
            ),
            StreamError::ShapeMismatch => {
                write!(f, "stream state does not match this design")
            }
        }
    }
}

/// Resumable snapshot of a streaming session's MC lanes: per-lane
/// packed (h, c) registers for every recurrent layer, plus the
/// position in the beat/mask schedule. Feeding a signal chunk-by-chunk
/// through one of these is bit-identical to one continuous pass
/// ([`Accelerator::predict_stream`]); the lane range `start..start +
/// count` makes the state MC-shardable — lane `k`'s state is a pure
/// function of `(design, session, beats consumed, k)`, so disjoint
/// ranges held by different engines evolve exactly the lanes a single
/// resident engine would.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamState {
    /// `[count][words_per_lane]` packed architectural state.
    words: Vec<u64>,
    words_per_lane: usize,
    /// Seed the whole session's mask schedule derives from.
    pub session_seed: u64,
    /// Completed beats (decisions already emitted).
    pub beats_done: u64,
    /// Timesteps already consumed of the in-progress beat.
    pub t_in_beat: usize,
    /// First MC sample lane this state holds.
    pub start: usize,
    /// MC sample lanes resident in this state.
    pub count: usize,
}

impl StreamState {
    /// Heap bytes this snapshot keeps resident — the unit the session
    /// table's byte budget charges.
    pub fn resident_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Total timesteps consumed since the session opened.
    pub fn timesteps_done(&self, seq_len: usize) -> u64 {
        self.beats_done * seq_len as u64 + self.t_in_beat as u64
    }
}

/// The synthesised design: engines, samplers, reuse factors, precision.
pub struct Accelerator {
    pub cfg: ArchConfig,
    pub reuse: ReuseFactors,
    pub precision: Precision,
    pub lstms: Vec<LstmEngine>,
    pub dense: DenseEngine,
    pub samplers: Vec<Option<BernoulliSampler>>,
    /// When true, MC predictions run the legacy per-sample loop (one
    /// full pass per sample, weights re-walked every time) instead of
    /// the blocked kernel path. Bit-identical output either way
    /// (tested below) — this is the bench baseline, not a feature.
    pub scalar_reference: bool,
    /// Kernel backend every engine MVM dispatches to
    /// (`docs/kernels.md` §Backends) — bit-identical across backends.
    pub kernel_backend: KernelBackend,
    /// Base LFSR seed the design was "synthesised" with; the fleet's
    /// seeded prediction path derives per-(request, sample) seeds from it.
    seed: u64,
    /// Seed-indexed mask bank shared across requests and engine
    /// workers (`--mask-bank-mb`, `docs/kernels.md` §Mask bank).
    /// `None` (the default) regenerates every mask — bit-identical to
    /// the bank either way; the bank only converts repeat seeds from
    /// LFSR streams into row copies.
    mask_bank: Option<Arc<MaskBank>>,
    /// Recurrent lane-steps computed since construction: one unit per
    /// (lane, layer, timestep) advanced. The streaming O(chunk)
    /// contract is asserted on deltas of this counter — a resumed
    /// chunk spends `chunk_timesteps x layers x lanes`, never the
    /// session's history.
    lane_steps: u64,
    // Scratch (no allocation in the hot loop).
    beat_q: Vec<Fx16>,
}

impl Accelerator {
    /// "Synthesise" the design from trained float parameters at the
    /// paper's Q6.10/Q12.20 precision.
    pub fn new(
        cfg: &ArchConfig,
        params: &Params,
        reuse: ReuseFactors,
        seed: u64,
    ) -> Self {
        Self::with_precision(cfg, params, reuse, seed, Precision::q16())
    }

    /// "Synthesise" the design at an explicit [`Precision`]: LSTM layer
    /// `l` is quantised at `precision.spec_for(l)`, the dense head at
    /// the default activation format.
    pub fn with_precision(
        cfg: &ArchConfig,
        params: &Params,
        reuse: ReuseFactors,
        seed: u64,
        precision: Precision,
    ) -> Self {
        let dims = cfg.lstm_dims();
        let mut lstms = Vec::with_capacity(dims.len());
        let mut samplers = Vec::with_capacity(dims.len());
        for (l, _) in dims.iter().enumerate() {
            let (wx, wh, b) = params.lstm(l);
            lstms.push(LstmEngine::with_format(
                wx,
                wh,
                b,
                reuse.rx,
                reuse.rh,
                cfg.bayes[l],
                precision.spec_for(l),
            ));
            samplers.push(if cfg.bayes[l] {
                Some(BernoulliSampler::new(seed ^ (l as u64 + 1) * 0x9E37))
            } else {
                None
            });
        }
        let (w, b) = params.dense();
        let dense =
            DenseEngine::with_format(w, b, reuse.rd, precision.default.act);
        Self {
            cfg: cfg.clone(),
            reuse,
            precision,
            lstms,
            dense,
            samplers,
            scalar_reference: false,
            kernel_backend: kernels::default_backend(),
            seed,
            mask_bank: None,
            lane_steps: 0,
            beat_q: Vec::new(),
        }
    }

    /// Recurrent (lane x layer x timestep) advances computed so far —
    /// the streaming cost meter (see the `lane_steps` field).
    pub fn lane_steps(&self) -> u64 {
        self.lane_steps
    }

    /// Attach (or detach) a shared seed-indexed mask bank. Output bits
    /// are unchanged in every case — the bank caches exactly the words
    /// the generator would produce (tested below).
    pub fn set_mask_bank(&mut self, bank: Option<Arc<MaskBank>>) {
        self.mask_bank = bank;
    }

    /// Switch every engine MVM to a kernel backend. Output bits are
    /// unchanged (the backend-equivalence contract, tested below);
    /// only the simulator's wall-clock cost shape moves. The
    /// structural per-sample loop is a separate axis
    /// ([`Accelerator::scalar_reference`]).
    pub fn set_kernel_backend(&mut self, backend: KernelBackend) {
        self.kernel_backend = backend;
        for e in self.lstms.iter_mut() {
            e.set_backend(backend);
        }
        self.dense.set_backend(backend);
    }

    /// Configure every engine for `rows` sample lanes (masks reset to
    /// all-ones, state zeroed).
    fn set_block(&mut self, rows: usize) {
        for e in self.lstms.iter_mut() {
            e.set_rows(rows);
        }
        self.dense.set_rows(rows);
    }

    /// Re-seed every Bayesian layer's LFSR bank from one sample seed —
    /// the hardware analogue of loading fresh LFSR init values over AXI
    /// before a pass. Layer salting matches [`Accelerator::new`].
    fn reseed_samplers(&mut self, sample_seed: u64) {
        for (l, slot) in self.samplers.iter_mut().enumerate() {
            if slot.is_some() {
                *slot = Some(BernoulliSampler::new(
                    sample_seed ^ (l as u64 + 1) * 0x9E37,
                ));
            }
        }
    }

    /// Pre-sample masks for lane `r` (Fig. 4 overlap) straight into the
    /// engines' bitplanes — the SIPO bit stream never expands into f32
    /// words. Per Bayesian layer the LFSR stream is consumed zx-then-zh,
    /// lanes in ascending order — exactly the per-pass order of the
    /// legacy per-sample loop, so blocked and scalar paths (and the
    /// pre-bitplane implementation) see identical bits
    /// (`fpga::engine::tests::fill_masks_row_matches_legacy_f32_fill_bit_for_bit`).
    fn presample_masks_row(&mut self, r: usize) {
        for (engine, slot) in
            self.lstms.iter_mut().zip(self.samplers.iter_mut())
        {
            if let Some(sampler) = slot {
                engine.fill_masks_row(r, || sampler.sample() != 0.0);
            }
        }
    }

    /// Seeded, word-level presample for lane `r` — the batched path's
    /// mask generator. Reseeds the layer samplers exactly like
    /// `reseed_samplers` + [`Accelerator::presample_masks_row`] and
    /// fills 64 bits per `keep_word` call instead of bit-by-bit —
    /// same draw order, same bits, same sampler end state (the
    /// `lfsr`/`engine` oracle tests pin all three). With a mask bank
    /// attached, a lane whose per-layer seed was seen before restores
    /// the cached row words verbatim instead of re-running the LFSRs.
    fn presample_masks_row_seeded(&mut self, r: usize, sample_seed: u64) {
        self.reseed_samplers(sample_seed);
        let bank = self.mask_bank.clone();
        for (l, (engine, slot)) in self
            .lstms
            .iter_mut()
            .zip(self.samplers.iter_mut())
            .enumerate()
        {
            let Some(sampler) = slot else { continue };
            let Some(bank) = bank.as_deref() else {
                engine.fill_masks_row_words(r, |n| sampler.keep_word(n));
                continue;
            };
            let key = MaskKey {
                layer_seed: sample_seed ^ (l as u64 + 1) * 0x9E37,
                zx_width: engine.zx.width(),
                zh_width: engine.zh.width(),
            };
            match bank.get(&key) {
                Some(words) => engine.set_mask_row_words(r, &words),
                None => {
                    engine
                        .fill_masks_row_words(r, |n| sampler.keep_word(n));
                    bank.insert(key, &engine.mask_row_words(r));
                }
            }
        }
    }

    /// Reusable inter-layer bus sized for `rows` lanes of the widest
    /// layer (no per-timestep allocation in the hot loop —
    /// EXPERIMENTS.md §Perf).
    fn make_bus(&self, rows: usize) -> Vec<Fx16> {
        let max_h = self
            .lstms
            .iter()
            .map(|e| e.hdim)
            .max()
            .unwrap_or(1)
            .max(self.cfg.input_dim);
        vec![Fx16::ZERO; rows * max_h]
    }

    /// Advance the encoder stack one timestep over all configured
    /// lanes: `bus` enters holding `[rows][input_dim]` quantised inputs
    /// at the first layer's format and leaves holding the last encoder
    /// layer's `[rows][hdim]` output. Where adjacent layers run at
    /// different formats the bus is requantised in place (a no-op on
    /// uniform designs — the bit-exactness contract at Q6.10). State is
    /// NOT reset here: one-shot passes reset before the first timestep,
    /// the streaming path deliberately resumes. Returns the bus
    /// content's (width, format).
    fn step_encoder_rows(
        &mut self,
        bus: &mut [Fx16],
        rows: usize,
    ) -> (usize, QFormat) {
        let nl = self.cfg.nl;
        let mut width = self.cfg.input_dim;
        let mut bus_fmt = self.lstms[0].act_format();
        for l in 0..nl {
            let lf = self.lstms[l].act_format();
            requantize_rows(&mut bus[..rows * width], bus_fmt, lf);
            let hd = self.lstms[l].hdim;
            let h = self.lstms[l].step_rows(bus, width);
            bus[..rows * hd].copy_from_slice(h);
            width = hd;
            bus_fmt = lf;
        }
        self.lane_steps += (rows * nl) as u64;
        (width, bus_fmt)
    }

    /// Run the classifier head on the encoder output held in `bus`:
    /// requantise to the head's format, dense MVM, dequantise, softmax
    /// per lane (ARM-side postprocess, as in the paper). Returns
    /// `[rows][K]` probabilities.
    fn classify_head_rows(
        &mut self,
        bus: &mut [Fx16],
        rows: usize,
        width: usize,
        bus_fmt: QFormat,
    ) -> Vec<f32> {
        let k = self.cfg.out_len();
        let dense_fmt = self.dense.fmt;
        requantize_rows(&mut bus[..rows * width], bus_fmt, dense_fmt);
        let logits = self.dense.step_rows(bus, width);
        let mut probs: Vec<f32> =
            logits.iter().map(|&v| dense_fmt.dequantize(v)).collect();
        for r in 0..rows {
            softmax_row(&mut probs[r * k..(r + 1) * k]);
        }
        probs
    }

    /// One blocked feedforward pass over the configured sample lanes.
    /// `row_beat[r]` selects which of `beats` lane `r` streams; masks
    /// must already be loaded (`set_block` + per-lane presample).
    /// Returns `[rows][out_len]` row-major.
    fn run_pass_rows(
        &mut self,
        beats: &[&[f32]],
        row_beat: &[usize],
    ) -> Vec<f32> {
        let t = self.cfg.seq_len;
        let idim = self.cfg.input_dim;
        let rows = row_beat.len();
        debug_assert!(rows >= 1);
        debug_assert_eq!(self.lstms[0].rows(), rows, "set_block first");
        // Quantise each DMA'd beat once, at the first layer's format.
        let in_fmt = self.lstms[0].act_format();
        self.beat_q.clear();
        for b in beats {
            debug_assert_eq!(b.len(), t * idim);
            self.beat_q.extend(b.iter().map(|&v| in_fmt.quantize(v)));
        }
        for e in self.lstms.iter_mut() {
            e.reset();
        }
        let nl = self.cfg.nl;
        let mut bus = self.make_bus(rows);
        // Stream the beats through the encoder stack, all lanes in
        // lockstep: every gate weight row fetched by a timestep serves
        // every lane (the blocked-kernel amortisation).
        let mut width = idim;
        let mut bus_fmt = in_fmt;
        for ti in 0..t {
            for (r, &b) in row_beat.iter().enumerate() {
                let src = b * t * idim + ti * idim;
                bus[r * idim..r * idim + idim]
                    .copy_from_slice(&self.beat_q[src..src + idim]);
            }
            let (w, f) = self.step_encoder_rows(&mut bus, rows);
            width = w;
            bus_fmt = f;
        }
        match self.cfg.task {
            Task::Anomaly => {
                // Bottleneck h_T cached for T steps, per lane.
                let emb: Vec<Fx16> = self.lstms[nl - 1].hidden().to_vec();
                let emb_fmt = self.lstms[nl - 1].act_format();
                let hb = self.lstms[nl - 1].hdim;
                let dense_o = self.cfg.dense_dims().1;
                let dense_fmt = self.dense.fmt;
                let out_len = self.cfg.out_len();
                let mut out = vec![0f32; rows * out_len];
                for ti in 0..t {
                    bus[..rows * hb].copy_from_slice(&emb);
                    width = hb;
                    bus_fmt = emb_fmt;
                    for l in nl..2 * nl {
                        let lf = self.lstms[l].act_format();
                        requantize_rows(&mut bus[..rows * width], bus_fmt, lf);
                        let hd = self.lstms[l].hdim;
                        let h = self.lstms[l].step_rows(&bus, width);
                        bus[..rows * hd].copy_from_slice(h);
                        width = hd;
                        bus_fmt = lf;
                    }
                    self.lane_steps += (rows * nl) as u64;
                    // Temporal dense on this step's decoder output (the
                    // univariate ECG reconstruction point, as in the
                    // single-lane pass).
                    requantize_rows(&mut bus[..rows * width], bus_fmt, dense_fmt);
                    let y = self.dense.step_rows(&bus, width);
                    for r in 0..rows {
                        out[r * out_len + ti] =
                            dense_fmt.dequantize(y[r * dense_o]);
                    }
                }
                out
            }
            Task::Classify => {
                self.classify_head_rows(&mut bus, rows, width, bus_fmt)
            }
        }
    }

    /// One feedforward pass of one beat (`[T]` for the univariate ECG).
    /// Returns the raw output (T reconstruction values or K probs).
    pub fn run_pass(&mut self, beat: &[f32]) -> Vec<f32> {
        self.set_block(1);
        self.presample_masks_row(0);
        self.run_pass_rows(&[beat], &[0])
    }

    /// Full Bayesian prediction: S MC passes with fresh LFSR masks
    /// (free-running sampler state — passes depend on sampler history).
    /// All S samples run as lanes of one blocked pass; each lane's
    /// masks are drawn from the free-running samplers in pass order, so
    /// the sample set is bit-identical to the legacy per-sample loop.
    pub fn predict(&mut self, beat: &[f32], s: usize) -> McOutput {
        let out_len = self.cfg.out_len();
        if s == 0 {
            // Degenerate S: keep the pre-kernel behaviour (empty sample
            // set) instead of configuring a zero-lane block.
            return McOutput { samples: Vec::new(), s: 0, out_len };
        }
        if self.scalar_reference {
            let mut samples = Vec::with_capacity(s * out_len);
            for _ in 0..s {
                samples.extend(self.run_pass(beat));
            }
            return McOutput { samples, s, out_len };
        }
        self.set_block(s);
        for r in 0..s {
            self.presample_masks_row(r);
        }
        let row_beat = vec![0usize; s];
        let samples = self.run_pass_rows(&[beat], &row_beat);
        McOutput { samples, s, out_len }
    }

    /// MC passes `start..start+count` of a request's sample schedule,
    /// with each pass's masks seeded as `mix3(design_seed, req_seed, k)`.
    /// Unlike [`Accelerator::predict`], sample `k` is a pure function of
    /// `(design_seed, req_seed, k)` — independent of sampler history — so
    /// splitting a request's S samples across fleet engines (MC-shard)
    /// reproduces exactly the sample set a single engine would compute.
    /// The whole shard runs as one blocked pass (`docs/kernels.md`).
    pub fn predict_seeded(
        &mut self,
        beat: &[f32],
        req_seed: u64,
        start: usize,
        count: usize,
    ) -> McOutput {
        if self.scalar_reference {
            return self.predict_seeded_scalar(beat, req_seed, start, count);
        }
        let req = BatchRequest { beat, req_seed, start, count };
        self.predict_batch_shards(&[req]).pop().expect("one request")
    }

    /// Legacy per-sample reference path: one full pass per sample, every
    /// weight matrix re-walked each time. Bit-identical to
    /// [`Accelerator::predict_seeded`] (tested below); kept as the
    /// equivalence oracle and the `mc_batch` bench baseline.
    pub fn predict_seeded_scalar(
        &mut self,
        beat: &[f32],
        req_seed: u64,
        start: usize,
        count: usize,
    ) -> McOutput {
        let out_len = self.cfg.out_len();
        let mut samples = Vec::with_capacity(count * out_len);
        for k in start..start + count {
            self.reseed_samplers(crate::rng::mix3(
                self.seed,
                req_seed,
                k as u64,
            ));
            samples.extend(self.run_pass(beat));
        }
        McOutput { samples, s: count, out_len }
    }

    /// Batched MC prediction — the fleet's blocked entry point: every
    /// request shard in `reqs` contributes `count` lanes to **one**
    /// blocked pass, so each weight row is fetched once per timestep
    /// for the whole batch instead of once per (request, sample).
    /// Lane (request `q`, sample `k`) reseeds its LFSRs from
    /// `mix3(design_seed, q.req_seed, k)` — bit-for-bit the
    /// [`Accelerator::predict_seeded`] schedule.
    pub fn predict_batch_shards(
        &mut self,
        reqs: &[BatchRequest],
    ) -> Vec<McOutput> {
        let out_len = self.cfg.out_len();
        if self.scalar_reference {
            let mut outs = Vec::with_capacity(reqs.len());
            for q in reqs {
                outs.push(self.predict_seeded_scalar(
                    q.beat, q.req_seed, q.start, q.count,
                ));
            }
            return outs;
        }
        let rows: usize = reqs.iter().map(|q| q.count).sum();
        if rows == 0 {
            // All-empty shards: answer with empty sample sets (the
            // pre-kernel predict_seeded behaviour for count = 0).
            return reqs
                .iter()
                .map(|_| McOutput { samples: Vec::new(), s: 0, out_len })
                .collect();
        }
        self.set_block(rows);
        let mut row_beat = Vec::with_capacity(rows);
        let mut r = 0;
        for (qi, q) in reqs.iter().enumerate() {
            for k in q.start..q.start + q.count {
                let sample_seed =
                    crate::rng::mix3(self.seed, q.req_seed, k as u64);
                self.presample_masks_row_seeded(r, sample_seed);
                row_beat.push(qi);
                r += 1;
            }
        }
        let beats: Vec<&[f32]> = reqs.iter().map(|q| q.beat).collect();
        let flat = self.run_pass_rows(&beats, &row_beat);
        let mut outs = Vec::with_capacity(reqs.len());
        let mut off = 0;
        for q in reqs {
            let n = q.count * out_len;
            outs.push(McOutput {
                samples: flat[off..off + n].to_vec(),
                s: q.count,
                out_len,
            });
            off += n;
        }
        outs
    }

    /// Batched fixed-S prediction over `beats`: `s` MC samples each,
    /// request `b` seeded by `req_seeds[b]`. One blocked pass computes
    /// the whole `[B x S]` lane grid; outputs are bit-identical to
    /// per-request [`Accelerator::predict_seeded`] calls.
    pub fn predict_batch(
        &mut self,
        beats: &[&[f32]],
        req_seeds: &[u64],
        s: usize,
    ) -> Vec<McOutput> {
        assert_eq!(beats.len(), req_seeds.len());
        let reqs: Vec<BatchRequest> = beats
            .iter()
            .zip(req_seeds)
            .map(|(&beat, &req_seed)| BatchRequest {
                beat,
                req_seed,
                start: 0,
                count: s,
            })
            .collect();
        self.predict_batch_shards(&reqs)
    }

    /// Adaptive Bayesian prediction: draw seeded MC passes incrementally
    /// and stop once the controller's confidence-interval rule fires
    /// (`docs/uncertainty.md`). Every pass goes through
    /// [`Accelerator::predict_seeded`], so sample `k` is bit-identical
    /// whether drawn here chunk-by-chunk, eagerly in one range, or on
    /// another fleet engine — and with early exit disabled
    /// (`target_ci <= 0`) the outcome reduces to exactly the fixed-S
    /// path's sample set.
    pub fn predict_adaptive(
        &mut self,
        beat: &[f32],
        req_seed: u64,
        cfg: &AdaptiveMcConfig,
    ) -> AdaptiveOutcome {
        let mut ctl = AdaptiveController::new(*cfg, self.cfg.out_len());
        let converged = loop {
            match ctl.decision() {
                McDecision::Draw { start, count } => {
                    let out =
                        self.predict_seeded(beat, req_seed, start, count);
                    ctl.push_block(start, out.samples);
                }
                McDecision::Converged => break true,
                McDecision::Exhausted => break false,
            }
        };
        let (mean, std) = ctl.acc.finalize();
        AdaptiveOutcome {
            mean,
            std,
            samples: ctl.acc.samples_ordered(),
            s_used: ctl.acc.count(),
            out_len: ctl.acc.out_len(),
            converged,
        }
    }

    /// Packed `u64` words one MC lane's full recurrent state occupies
    /// on this design (every layer's (h, c) registers).
    pub fn state_words_per_lane(&self) -> usize {
        self.lstms.iter().map(|e| e.state_words_per_row()).sum()
    }

    /// Resident bytes one MC lane of stream state costs — what the
    /// coordinator's session table charges its byte budget per lane.
    pub fn state_bytes_per_lane(&self) -> usize {
        self.state_words_per_lane() * 8
    }

    fn save_lane_state(&self, r: usize, out: &mut [u64]) {
        let mut off = 0;
        for e in &self.lstms {
            let w = e.state_words_per_row();
            out[off..off + w].copy_from_slice(&e.state_row_words(r));
            off += w;
        }
    }

    fn load_lane_state(&mut self, r: usize, words: &[u64]) {
        let mut off = 0;
        for e in self.lstms.iter_mut() {
            let w = e.state_words_per_row();
            e.set_state_row_words(r, &words[off..off + w]);
            off += w;
        }
    }

    /// Load the in-progress beat's masks into every resident lane.
    /// Masks are a pure function of `(design, session, beat, k)` —
    /// see [`stream_req_seed`] — so a resumed (or replayed, or
    /// re-sharded) state re-derives exactly the bits the continuous
    /// pass used, and the mask bank converts the re-derivation into
    /// row copies when attached.
    fn presample_stream_masks(&mut self, st: &StreamState) {
        let req_seed = stream_req_seed(st.session_seed, st.beats_done);
        for k in 0..st.count {
            let sample_seed = crate::rng::mix3(
                self.seed,
                req_seed,
                (st.start + k) as u64,
            );
            self.presample_masks_row_seeded(k, sample_seed);
        }
    }

    /// Open a resumable stream over MC lanes `start..start + count`:
    /// zeroed recurrent state at beat 0, timestep 0. The first beat fed
    /// through this state is bit-identical to
    /// `predict_seeded(beat, stream_req_seed(session_seed, 0), start,
    /// count)` — both start from zero state with the same mask
    /// schedule; subsequent beats keep the state resident (the
    /// continuous-monitoring semantics) instead of resetting.
    pub fn open_stream(
        &self,
        session_seed: u64,
        start: usize,
        count: usize,
    ) -> StreamState {
        let words_per_lane = self.state_words_per_lane();
        StreamState {
            words: vec![0u64; count * words_per_lane],
            words_per_lane,
            session_seed,
            beats_done: 0,
            t_in_beat: 0,
            start,
            count,
        }
    }

    /// Resumable streaming prediction: consume `signal` (a whole number
    /// of timesteps, any chunking) through the resident state, emitting
    /// one MC decision per completed beat (`seq_len` timesteps). The
    /// contract is **bitwise**: any split of a signal into chunks —
    /// across calls, across engines holding disjoint lane ranges, or
    /// across an eviction + replay — produces exactly the decisions of
    /// one continuous pass. Cost is O(chunk x layers x lanes)
    /// ([`Accelerator::lane_steps`] meters it); prior history is never
    /// recomputed.
    pub fn predict_stream(
        &mut self,
        st: &mut StreamState,
        signal: &[f32],
    ) -> Result<Vec<McOutput>, StreamError> {
        if self.cfg.task != Task::Classify {
            return Err(StreamError::UnsupportedTask);
        }
        let idim = self.cfg.input_dim;
        if signal.len() % idim != 0 {
            return Err(StreamError::RaggedChunk {
                len: signal.len(),
                idim,
            });
        }
        if st.words_per_lane != self.state_words_per_lane()
            || st.words.len() != st.count * st.words_per_lane
        {
            return Err(StreamError::ShapeMismatch);
        }
        let t = self.cfg.seq_len;
        let n_steps = signal.len() / idim;
        let rows = st.count;
        let out_len = self.cfg.out_len();
        if rows == 0 {
            // Zero-lane shard: track the schedule position (so merges
            // stay aligned) and answer empty sample sets, the
            // predict_seeded count = 0 behaviour.
            let total = st.t_in_beat + n_steps;
            let beats = total / t;
            st.beats_done += beats as u64;
            st.t_in_beat = total % t;
            return Ok((0..beats)
                .map(|_| McOutput { samples: Vec::new(), s: 0, out_len })
                .collect());
        }
        if n_steps == 0 {
            return Ok(Vec::new());
        }
        self.set_block(rows);
        for k in 0..rows {
            self.load_lane_state(
                k,
                &st.words[k * st.words_per_lane..(k + 1) * st.words_per_lane],
            );
        }
        self.presample_stream_masks(st);
        // Quantise the chunk once, at the first layer's format —
        // identical per-element arithmetic to the one-shot beat
        // quantisation, so chunk boundaries cannot move bits.
        let in_fmt = self.lstms[0].act_format();
        self.beat_q.clear();
        self.beat_q.extend(signal.iter().map(|&v| in_fmt.quantize(v)));
        let mut bus = self.make_bus(rows);
        let mut outs = Vec::new();
        for ti in 0..n_steps {
            // All MC lanes of a session stream the same signal.
            for r in 0..rows {
                bus[r * idim..r * idim + idim].copy_from_slice(
                    &self.beat_q[ti * idim..(ti + 1) * idim],
                );
            }
            let (width, bus_fmt) = self.step_encoder_rows(&mut bus, rows);
            st.t_in_beat += 1;
            if st.t_in_beat == t {
                // Beat boundary: decision from the resident state, then
                // advance the per-beat mask schedule. The recurrent
                // state is NOT reset — the stream carries context
                // across beats.
                let probs =
                    self.classify_head_rows(&mut bus, rows, width, bus_fmt);
                outs.push(McOutput { samples: probs, s: rows, out_len });
                st.t_in_beat = 0;
                st.beats_done += 1;
                // Next beat's masks — skipped when the chunk ends here
                // (the next call re-derives them from `beats_done`).
                if ti + 1 < n_steps {
                    self.presample_stream_masks(st);
                }
            }
        }
        for k in 0..rows {
            let range =
                k * st.words_per_lane..(k + 1) * st.words_per_lane;
            let mut snap = vec![0u64; st.words_per_lane];
            self.save_lane_state(k, &mut snap);
            st.words[range].copy_from_slice(&snap);
        }
        Ok(outs)
    }

    /// Post-synthesis resource report (the Table III "Used" row).
    pub fn resources_synthesized(&self) -> ResourceEstimate {
        // The autoencoder's temporal dense must sustain one output per
        // pipeline timestep, so synthesis allocates ceil(F*O*T/R_d)
        // multipliers across the timestep pipeline (the paper's H*O*T/R_d
        // term); the classifier head fires once per sequence and its tiny
        // MVM can fold into fabric.
        let dense_dsps = match self.cfg.task {
            Task::Anomaly => {
                let (f, o) = self.cfg.dense_dims();
                let pack = self.dense.fmt.macs_per_dsp() as usize;
                ((f * o * self.cfg.seq_len).div_ceil(self.reuse.rd * pack))
                    as u64
            }
            Task::Classify => self.dense.dsps_synthesized(),
        };
        let dsps: u64 = self
            .lstms
            .iter()
            .map(LstmEngine::dsps_synthesized)
            .sum::<u64>()
            + dense_dsps;
        // LUT/FF/BRAM from the analytic model (fabric is not re-estimated
        // by the simulator; DSPs are the contended resource).
        let analytic =
            ResourceModel::estimate_q(&self.cfg, &self.reuse, &self.precision);
        ResourceEstimate {
            dsps: dsps as f64,
            luts: analytic.luts,
            ffs: analytic.ffs,
            brams: analytic.brams,
        }
    }

    /// Analytic estimate for the same design (the Sec. IV-B model) —
    /// compared against `resources_synthesized` for the 98% claim.
    pub fn resources_estimated(&self) -> ResourceEstimate {
        ResourceModel::estimate_q(&self.cfg, &self.reuse, &self.precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{Masks, Model};
    use crate::rng::Rng;

    fn short_cfg(task: Task) -> ArchConfig {
        let mut cfg = match task {
            Task::Anomaly => ArchConfig::new(Task::Anomaly, 8, 1, "NN"),
            Task::Classify => ArchConfig::new(Task::Classify, 8, 2, "NN"),
        };
        cfg.seq_len = 24;
        cfg
    }

    #[test]
    fn classifier_probs_sum_to_one() {
        let cfg = short_cfg(Task::Classify);
        let params = Params::init(&cfg, &mut Rng::new(0));
        let mut acc =
            Accelerator::new(&cfg, &params, ReuseFactors::new(2, 1, 1), 7);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.3).sin()).collect();
        let probs = acc.run_pass(&beat);
        assert_eq!(probs.len(), 4);
        let s: f32 = probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn fixed_point_tracks_float_model() {
        // The quantised accelerator must approximate the float engine on
        // the same weights (Tables I/II premise).
        for task in [Task::Anomaly, Task::Classify] {
            let cfg = short_cfg(task);
            let mut rng = Rng::new(4);
            let model = Model::init(cfg.clone(), &mut rng);
            let mut acc = Accelerator::new(
                &cfg,
                &model.params,
                ReuseFactors::new(1, 1, 1),
                3,
            );
            let beat: Vec<f32> = (0..cfg.seq_len)
                .map(|i| (i as f32 * 0.37).sin())
                .collect();
            let fx = acc.run_pass(&beat);
            let fl = model.forward(&beat, 1, &Masks::ones(&cfg, 1));
            assert_eq!(fx.len(), fl.len());
            let rmse = crate::metrics::rmse(&fx, &fl);
            assert!(
                rmse < 0.05,
                "task {task:?}: fixed-point drifted, rmse {rmse}"
            );
        }
    }

    #[test]
    fn pointwise_design_is_deterministic() {
        let cfg = short_cfg(Task::Classify);
        let params = Params::init(&cfg, &mut Rng::new(2));
        let mut acc =
            Accelerator::new(&cfg, &params, ReuseFactors::new(1, 1, 1), 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let a = acc.run_pass(&beat);
        let b = acc.run_pass(&beat);
        assert_eq!(a, b);
    }

    #[test]
    fn bayesian_design_varies_across_mc_samples() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let mut acc =
            Accelerator::new(&cfg, &params, ReuseFactors::new(1, 1, 1), 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let out = acc.predict(&beat, 8);
        assert_eq!(out.samples.len(), 8 * 4);
        // At least two samples must differ (MCD active).
        let first = &out.samples[0..4];
        assert!(
            (1..8).any(|s| &out.samples[s * 4..s * 4 + 4] != first),
            "MC samples identical — dropout inactive?"
        );
        // Mean is still a distribution.
        let m = out.mean();
        assert!((m.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }

    /// Seeded prediction is a pure function of (design seed, request
    /// seed, sample index): shards concatenated in order must be
    /// bit-identical to one whole-range pass — the MC-shard invariant.
    #[test]
    fn seeded_shards_concatenate_to_whole() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let reuse = ReuseFactors::new(1, 1, 1);
        let mut whole = Accelerator::new(&cfg, &params, reuse, 9);
        let all = whole.predict_seeded(&beat, 77, 0, 8);

        let mut sharded = Accelerator::new(&cfg, &params, reuse, 9);
        let mut cat = Vec::new();
        for (start, count) in [(0usize, 3usize), (3, 3), (6, 2)] {
            cat.extend(sharded.predict_seeded(&beat, 77, start, count).samples);
        }
        assert_eq!(all.samples, cat, "shard union must equal whole range");

        // A different request seed must change the sample set.
        let other = sharded.predict_seeded(&beat, 78, 0, 8);
        assert_ne!(all.samples, other.samples);

        // Samples still vary across k (dropout active).
        let first = &all.samples[0..4];
        assert!((1..8).any(|s| &all.samples[s * 4..s * 4 + 4] != first));
    }

    /// Determinism invariant (ISSUE 2 acceptance): with early exit
    /// disabled the adaptive path must be *bit-identical* to the fixed-S
    /// seeded path — same samples, same reduction order.
    #[test]
    fn adaptive_with_no_early_exit_matches_fixed_path_bitwise() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let reuse = ReuseFactors::new(1, 1, 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let s_max = 10;

        // Fixed-S reference: one eager seeded range, reduced the
        // canonical way (ascending-k moment sums -> pooled mean/std).
        let mut fixed = Accelerator::new(&cfg, &params, reuse, 9);
        let whole = fixed.predict_seeded(&beat, 55, 0, s_max);
        let mut acc = crate::uq::McAccumulator::new(whole.out_len);
        acc.push_block(0, whole.samples.clone());
        let (fm, fs) = acc.finalize();

        // Adaptive with target_ci = 0: draws chunks until s_max.
        let mut adaptive = Accelerator::new(&cfg, &params, reuse, 9);
        let mc = AdaptiveMcConfig {
            s_min: 3,
            s_max,
            target_ci: 0.0,
            z: 1.96,
            chunk: 4,
        };
        let out = adaptive.predict_adaptive(&beat, 55, &mc);
        assert_eq!(out.s_used, s_max, "no early exit at target_ci = 0");
        assert!(!out.converged);
        assert_eq!(out.samples, whole.samples, "identical sample set");
        assert_eq!(out.mean, fm, "bit-identical mean");
        assert_eq!(out.std, fs, "bit-identical std");
    }

    #[test]
    fn adaptive_early_exit_saves_samples_and_stays_in_envelope() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(4));
        let mut acc = Accelerator::new(
            &cfg,
            &params,
            ReuseFactors::new(2, 1, 1),
            7,
        );
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.3).sin()).collect();
        // Probabilities live in [0, 1]: per-point std <= 0.5, so the CI
        // half-width at s_min = 4 is <= 1.96*0.5/2 < 1.0 — a target of
        // 1.0 must always converge at exactly s_min.
        let mc = AdaptiveMcConfig {
            s_min: 4,
            s_max: 32,
            target_ci: 1.0,
            z: 1.96,
            chunk: 4,
        };
        let out = acc.predict_adaptive(&beat, 3, &mc);
        assert!(out.converged);
        assert_eq!(out.s_used, 4, "easy target converges at s_min");
        assert_eq!(out.samples.len(), out.s_used * out.out_len);
        assert!((out.mean.iter().sum::<f32>() - 1.0).abs() < 1e-4);

        // An impossible target exhausts the budget instead.
        let hard = AdaptiveMcConfig { target_ci: 1e-12, ..mc };
        let out = acc.predict_adaptive(&beat, 3, &hard);
        assert!(!out.converged);
        assert_eq!(out.s_used, 32);
    }

    /// ISSUE 3 acceptance: the blocked batch path is bit-identical to
    /// per-request `predict_seeded` for every request in the batch, for
    /// both topologies, mixed shard ranges included.
    #[test]
    fn predict_batch_matches_per_request_predict_seeded_bitwise() {
        for task in [Task::Classify, Task::Anomaly] {
            let mut cfg = match task {
                Task::Classify => ArchConfig::new(Task::Classify, 8, 2, "YY"),
                Task::Anomaly => ArchConfig::new(Task::Anomaly, 8, 1, "YY"),
            };
            cfg.seq_len = 24;
            let params = Params::init(&cfg, &mut Rng::new(2));
            let reuse = ReuseFactors::new(1, 1, 1);
            let beats: Vec<Vec<f32>> = (0..3)
                .map(|b| {
                    (0..cfg.seq_len)
                        .map(|i| (i as f32 * (0.2 + 0.1 * b as f32)).cos())
                        .collect()
                })
                .collect();
            let seeds = [77u64, 78, 79];
            let s = 5;

            let mut batched = Accelerator::new(&cfg, &params, reuse, 9);
            let beat_refs: Vec<&[f32]> =
                beats.iter().map(|b| b.as_slice()).collect();
            let outs = batched.predict_batch(&beat_refs, &seeds, s);

            let mut single = Accelerator::new(&cfg, &params, reuse, 9);
            for (b, out) in outs.iter().enumerate() {
                let want = single.predict_seeded(&beats[b], seeds[b], 0, s);
                assert_eq!(out.s, s);
                assert_eq!(
                    out.samples, want.samples,
                    "task {task:?}, request {b}: batch lane must equal \
                     the per-request seeded prediction bit-for-bit"
                );
            }

            // Heterogeneous shard ranges through the same blocked call.
            let mut sharded = Accelerator::new(&cfg, &params, reuse, 9);
            let reqs = [
                BatchRequest {
                    beat: &beats[0],
                    req_seed: seeds[0],
                    start: 2,
                    count: 3,
                },
                BatchRequest {
                    beat: &beats[1],
                    req_seed: seeds[1],
                    start: 0,
                    count: 1,
                },
            ];
            let outs = sharded.predict_batch_shards(&reqs);
            for (q, out) in reqs.iter().zip(&outs) {
                let want = single.predict_seeded(
                    q.beat, q.req_seed, q.start, q.count,
                );
                assert_eq!(out.samples, want.samples, "shard range");
            }
        }
    }

    /// The blocked kernel path and the legacy per-sample scalar loop
    /// are bit-identical — for the seeded schedule and the free-running
    /// sampler path alike.
    #[test]
    fn blocked_path_matches_scalar_reference_bitwise() {
        for task in [Task::Classify, Task::Anomaly] {
            let mut cfg = match task {
                Task::Classify => ArchConfig::new(Task::Classify, 8, 2, "YN"),
                Task::Anomaly => ArchConfig::new(Task::Anomaly, 8, 1, "YY"),
            };
            cfg.seq_len = 24;
            let params = Params::init(&cfg, &mut Rng::new(6));
            let reuse = ReuseFactors::new(2, 1, 1);
            let beat: Vec<f32> = (0..cfg.seq_len)
                .map(|i| (i as f32 * 0.21).sin())
                .collect();

            let mut blocked = Accelerator::new(&cfg, &params, reuse, 11);
            let mut scalar = Accelerator::new(&cfg, &params, reuse, 11);
            scalar.scalar_reference = true;

            let b = blocked.predict_seeded(&beat, 5, 1, 7);
            let s = scalar.predict_seeded(&beat, 5, 1, 7);
            assert_eq!(b.samples, s.samples, "task {task:?}: seeded path");

            let b = blocked.predict(&beat, 6);
            let s = scalar.predict(&beat, 6);
            assert_eq!(
                b.samples, s.samples,
                "task {task:?}: free-running path"
            );
        }
    }

    /// Accelerator-level leg of the backend-equivalence contract:
    /// every kernel backend — and the structural per-sample scalar
    /// loop — computes bit-identical sample sets on the seeded and
    /// batched paths, at q16 and at a packed narrow precision.
    #[test]
    fn all_kernel_backends_bit_identical_at_accel_level() {
        for prec in [Precision::q16(), Precision::q8()] {
            let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
            cfg.seq_len = 24;
            let params = Params::init(&cfg, &mut Rng::new(2));
            let reuse = ReuseFactors::new(1, 1, 1);
            let beat: Vec<f32> = (0..cfg.seq_len)
                .map(|i| (i as f32 * 0.2).cos())
                .collect();
            let build = |backend: KernelBackend| {
                let mut a = Accelerator::with_precision(
                    &cfg, &params, reuse, 9, prec.clone(),
                );
                a.set_kernel_backend(backend);
                a
            };
            let want = build(KernelBackend::Blocked)
                .predict_seeded(&beat, 77, 1, 6);
            for backend in KernelBackend::ALL {
                let mut acc = build(backend);
                assert_eq!(acc.kernel_backend, backend);
                let got = acc.predict_seeded(&beat, 77, 1, 6);
                assert_eq!(
                    got.samples,
                    want.samples,
                    "{} {}: seeded path drifted",
                    prec.name(),
                    backend.name()
                );
                let batch =
                    acc.predict_batch(&[&beat, &beat], &[77, 78], 4);
                let mut blocked = build(KernelBackend::Blocked);
                let wb = blocked.predict_batch(&[&beat, &beat], &[77, 78], 4);
                for (g, w) in batch.iter().zip(&wb) {
                    assert_eq!(
                        g.samples,
                        w.samples,
                        "{} {}: batched path drifted",
                        prec.name(),
                        backend.name()
                    );
                }
            }
            // The structural scalar loop agrees under any backend too.
            let mut scalar = build(KernelBackend::Simd);
            scalar.scalar_reference = true;
            assert_eq!(
                scalar.predict_seeded(&beat, 77, 1, 6).samples,
                want.samples,
                "{}: per-sample loop drifted",
                prec.name()
            );
        }
    }

    /// Mask-bank contract at the accelerator level: bank on == bank
    /// off bit-for-bit, cold and warm; repeat seeds hit; MC-shard
    /// splits through a shared bank still concatenate to the whole.
    #[test]
    fn mask_bank_is_bit_identical_and_hits_on_repeat_seeds() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let reuse = ReuseFactors::new(1, 1, 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();

        let mut plain = Accelerator::new(&cfg, &params, reuse, 9);
        let want = plain.predict_seeded(&beat, 77, 0, 8);

        let bank = Arc::new(MaskBank::new(4 << 20));
        let mut banked = Accelerator::new(&cfg, &params, reuse, 9);
        banked.set_mask_bank(Some(bank.clone()));

        // Cold pass: all misses, identical bits.
        let cold = banked.predict_seeded(&beat, 77, 0, 8);
        assert_eq!(cold.samples, want.samples, "cold bank drifted");
        let s0 = bank.stats();
        assert_eq!(s0.hits, 0, "distinct (seed, k) lanes cannot hit cold");
        assert_eq!(s0.misses, 2 * 8, "2 Bayesian layers x 8 lanes");
        assert!(s0.resident_bytes > 0);

        // Warm pass, same request seed: every lane-layer hits.
        let warm = banked.predict_seeded(&beat, 77, 0, 8);
        assert_eq!(warm.samples, want.samples, "warm bank drifted");
        let s1 = bank.stats();
        assert_eq!(s1.hits, 2 * 8, "warm pass must hit every lane-layer");
        assert_eq!(s1.misses, s0.misses, "no new misses when warm");

        // A different request seed misses again and stays correct.
        let mut plain2 = Accelerator::new(&cfg, &params, reuse, 9);
        let other = banked.predict_seeded(&beat, 78, 0, 8);
        assert_eq!(
            other.samples,
            plain2.predict_seeded(&beat, 78, 0, 8).samples
        );
        assert!(bank.stats().misses > s1.misses);

        // MC-shard invariance through a shared bank: two accelerators
        // (distinct fleet engines) splitting the warm request's range
        // reproduce the whole bit-for-bit, hitting the shared bank.
        let mut e1 = Accelerator::new(&cfg, &params, reuse, 9);
        let mut e2 = Accelerator::new(&cfg, &params, reuse, 9);
        e1.set_mask_bank(Some(bank.clone()));
        e2.set_mask_bank(Some(bank.clone()));
        let hits_before = bank.stats().hits;
        let mut cat = e1.predict_seeded(&beat, 77, 0, 3).samples;
        cat.extend(e2.predict_seeded(&beat, 77, 3, 5).samples);
        assert_eq!(cat, want.samples, "sharded-through-bank drifted");
        assert_eq!(
            bank.stats().hits,
            hits_before + 2 * 8,
            "shards reuse the warm rows"
        );
    }

    /// The batched word-level presample (with and without a bank) is
    /// bit-identical to the legacy per-sample scalar loop — the
    /// cross-path oracle now also covers the word fill.
    #[test]
    fn banked_batch_path_matches_scalar_reference_bitwise() {
        let mut cfg = ArchConfig::new(Task::Anomaly, 8, 1, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(6));
        let reuse = ReuseFactors::new(2, 1, 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.21).sin()).collect();
        let mut scalar = Accelerator::new(&cfg, &params, reuse, 11);
        scalar.scalar_reference = true;
        let want = scalar.predict_seeded(&beat, 5, 1, 7);
        let mut banked = Accelerator::new(&cfg, &params, reuse, 11);
        banked.set_mask_bank(Some(Arc::new(MaskBank::new(1 << 20))));
        for round in 0..2 {
            let got = banked.predict_seeded(&beat, 5, 1, 7);
            assert_eq!(got.samples, want.samples, "round {round}");
        }
    }

    /// Interleaving blocked batch calls with single-lane passes must
    /// not leak lane state (set_block reconfigures cleanly both ways).
    #[test]
    fn block_size_changes_do_not_leak_state() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(3));
        let reuse = ReuseFactors::new(1, 1, 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut acc = Accelerator::new(&cfg, &params, reuse, 5);
        let first = acc.predict_seeded(&beat, 1, 0, 4);
        let _ = acc.predict_batch(&[&beat, &beat], &[2, 3], 6);
        let _ = acc.run_pass(&beat);
        let again = acc.predict_seeded(&beat, 1, 0, 4);
        assert_eq!(first.samples, again.samples);
    }

    /// Degenerate S = 0 keeps the pre-kernel behaviour: empty sample
    /// set, no panic (the blocked path must not configure a zero-lane
    /// block).
    #[test]
    fn zero_samples_yield_empty_output() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(3));
        let mut acc = Accelerator::new(
            &cfg,
            &params,
            ReuseFactors::new(1, 1, 1),
            5,
        );
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.3).sin()).collect();
        let out = acc.predict(&beat, 0);
        assert_eq!(out.s, 0);
        assert!(out.samples.is_empty());
        let out = acc.predict_seeded(&beat, 1, 4, 0);
        assert_eq!(out.s, 0);
        assert!(out.samples.is_empty());
        // Mixed batch: empty shards ride along with real ones.
        let outs = acc.predict_batch_shards(&[
            BatchRequest { beat: &beat, req_seed: 1, start: 0, count: 2 },
            BatchRequest { beat: &beat, req_seed: 2, start: 0, count: 0 },
        ]);
        assert_eq!(outs[0].s, 2);
        assert_eq!(outs[1].s, 0);
        assert!(outs[1].samples.is_empty());
    }

    #[test]
    fn mean_std_walks_once_and_matches_accessors() {
        let out = McOutput {
            samples: vec![0.2, 0.8, 0.6, 0.4, 0.5, 0.5],
            s: 3,
            out_len: 2,
        };
        let (mean, std) = out.mean_std();
        assert_eq!(mean, out.mean());
        assert_eq!(std, out.std());
        assert!((mean[0] - (0.2 + 0.6 + 0.5) / 3.0).abs() < 1e-6);
    }

    #[test]
    fn reuse_factors_do_not_change_numerics() {
        let cfg = short_cfg(Task::Anomaly);
        let params = Params::init(&cfg, &mut Rng::new(5));
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.15).sin()).collect();
        let mut a1 =
            Accelerator::new(&cfg, &params, ReuseFactors::new(1, 1, 1), 1);
        let mut a2 =
            Accelerator::new(&cfg, &params, ReuseFactors::new(8, 4, 2), 1);
        assert_eq!(a1.run_pass(&beat), a2.run_pass(&beat));
        // But they do change resources.
        assert!(
            a2.resources_synthesized().dsps < a1.resources_synthesized().dsps
        );
    }

    /// Accelerator-level half of the Q6.10 contract: the parametric
    /// constructor at `Precision::q16()` — including an explicit
    /// all-layers-q16 override set — is bit-identical to
    /// `Accelerator::new`, across both topologies and both kernel paths.
    #[test]
    fn q16_precision_bit_identical_to_legacy_constructor() {
        use crate::fixedpoint::QuantSpec;
        for task in [Task::Classify, Task::Anomaly] {
            let mut cfg = match task {
                Task::Classify => ArchConfig::new(Task::Classify, 8, 2, "YY"),
                Task::Anomaly => ArchConfig::new(Task::Anomaly, 8, 1, "YY"),
            };
            cfg.seq_len = 24;
            let params = Params::init(&cfg, &mut Rng::new(2));
            let reuse = ReuseFactors::new(1, 1, 1);
            let beat: Vec<f32> = (0..cfg.seq_len)
                .map(|i| (i as f32 * 0.2).cos())
                .collect();
            let mut legacy = Accelerator::new(&cfg, &params, reuse, 9);
            let want = legacy.predict_seeded(&beat, 77, 0, 6);

            let mut uniform = Accelerator::with_precision(
                &cfg,
                &params,
                reuse,
                9,
                Precision::q16(),
            );
            assert_eq!(
                uniform.predict_seeded(&beat, 77, 0, 6).samples,
                want.samples,
                "{task:?}: uniform q16"
            );

            // Explicit per-layer overrides that all resolve to q16 must
            // not perturb a single bit (the requantise hook is a no-op).
            let mut overridden = Precision::q16();
            for l in 0..cfg.num_lstm_layers() {
                overridden = overridden.with_layer(l, QuantSpec::q16());
            }
            let mut explicit = Accelerator::with_precision(
                &cfg, &params, reuse, 9, overridden,
            );
            assert_eq!(
                explicit.predict_seeded(&beat, 77, 0, 6).samples,
                want.samples,
                "{task:?}: per-layer q16 overrides"
            );

            // The scalar-reference loop agrees at q16 too.
            let mut scalar = Accelerator::with_precision(
                &cfg,
                &params,
                reuse,
                9,
                Precision::q16(),
            );
            scalar.scalar_reference = true;
            assert_eq!(
                scalar.predict_seeded(&beat, 77, 0, 6).samples,
                want.samples,
                "{task:?}: scalar reference at q16"
            );
        }
    }

    /// Narrow uniform precisions still track the float model, with a
    /// coarser error bound — the accuracy axis the DSE measures.
    #[test]
    fn narrow_precisions_track_float_loosely() {
        for (prec, tol) in [
            (Precision::q12(), 0.1f32),
            (Precision::q8(), 0.3),
        ] {
            let cfg = short_cfg(Task::Classify);
            let mut rng = Rng::new(4);
            let model = Model::init(cfg.clone(), &mut rng);
            let mut acc = Accelerator::with_precision(
                &cfg,
                &model.params,
                ReuseFactors::new(1, 1, 1),
                3,
                prec.clone(),
            );
            let beat: Vec<f32> = (0..cfg.seq_len)
                .map(|i| (i as f32 * 0.37).sin())
                .collect();
            let fx = acc.run_pass(&beat);
            let fl = model.forward(&beat, 1, &Masks::ones(&cfg, 1));
            let rmse = crate::metrics::rmse(&fx, &fl);
            assert!(
                rmse < tol,
                "{}: drifted too far from float, rmse {rmse}",
                prec.name()
            );
        }
    }

    /// Per-layer mixed precision runs end to end: deterministic, valid
    /// probabilities, and actually different bits from the uniform q16
    /// design (the override is live).
    #[test]
    fn mixed_per_layer_precision_runs_and_differs() {
        use crate::fixedpoint::QuantSpec;
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let reuse = ReuseFactors::new(1, 1, 1);
        let beat: Vec<f32> =
            (0..cfg.seq_len).map(|i| (i as f32 * 0.2).cos()).collect();
        let prec = Precision::q16().with_layer(1, QuantSpec::q8());
        let mut mixed =
            Accelerator::with_precision(&cfg, &params, reuse, 9, prec);
        let a = mixed.predict_seeded(&beat, 5, 0, 4);
        let b = mixed.predict_seeded(&beat, 5, 0, 4);
        assert_eq!(a.samples, b.samples, "mixed precision is deterministic");
        for row in a.samples.chunks_exact(a.out_len) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        }
        let mut q16 = Accelerator::new(&cfg, &params, reuse, 9);
        let w = q16.predict_seeded(&beat, 5, 0, 4);
        assert_ne!(
            a.samples, w.samples,
            "a q8 layer override must change the computed bits"
        );
    }

    /// Narrower precision shrinks the synthesised DSP footprint (the
    /// resource axis the DSE trades against accuracy).
    #[test]
    fn narrower_precision_uses_fewer_resources() {
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let params = Params::init(&cfg, &mut Rng::new(0));
        let reuse = ReuseFactors::new(2, 1, 1);
        let q16 =
            Accelerator::new(&cfg, &params, reuse, 0).resources_synthesized();
        let q8 = Accelerator::with_precision(
            &cfg,
            &params,
            reuse,
            0,
            Precision::q8(),
        )
        .resources_synthesized();
        assert!(q8.dsps < q16.dsps, "{} !< {}", q8.dsps, q16.dsps);
        assert!(q8.luts < q16.luts);
        assert!(q8.brams < q16.brams);
    }

    /// Fixture for the streaming tests: 2-layer Bayesian classifier,
    /// short beats, and a multi-beat synthetic signal.
    fn stream_fixture() -> (ArchConfig, Params, Vec<f32>) {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        let signal: Vec<f32> = (0..3 * cfg.seq_len)
            .map(|i| {
                (i as f32 * 0.13).sin() + 0.3 * (i as f32 * 0.05).cos()
            })
            .collect();
        (cfg, params, signal)
    }

    /// The streaming tentpole contract: feeding a signal chunk-by-chunk
    /// through a resumed [`StreamState`] — any chunking, mid-beat
    /// splits included, with unrelated one-shot work interleaved on the
    /// same engines, with or without a mask bank — produces exactly the
    /// decisions of one continuous pass. The first beat is additionally
    /// anchored to `predict_seeded` (cross-path oracle), and later
    /// beats are shown to actually carry state.
    #[test]
    fn stream_chunked_matches_one_continuous_pass_bitwise() {
        let (cfg, params, signal) = stream_fixture();
        let reuse = ReuseFactors::new(1, 1, 1);
        let t = cfg.seq_len;
        let (s, sid) = (6usize, 0xABCDu64);

        let mut one = Accelerator::new(&cfg, &params, reuse, 9);
        let mut st = one.open_stream(sid, 0, s);
        let whole = one.predict_stream(&mut st, &signal).unwrap();
        assert_eq!(whole.len(), 3, "one decision per completed beat");
        assert_eq!(st.beats_done, 3);
        assert_eq!(st.t_in_beat, 0);
        for out in &whole {
            assert_eq!(out.s, s);
            for row in out.samples.chunks_exact(out.out_len) {
                assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            }
        }

        // Cross-path anchor: beat 0 from zero state is bit-identical to
        // the seeded one-shot path under the session's beat-0 seed.
        let mut seeded = Accelerator::new(&cfg, &params, reuse, 9);
        let want0 =
            seeded.predict_seeded(&signal[..t], stream_req_seed(sid, 0), 0, s);
        assert_eq!(whole[0].samples, want0.samples, "beat-0 anchor");

        // Beat 1 carries the session's resident state — a stateless
        // one-shot of the same window under the same mask seed differs.
        let want1 = seeded.predict_seeded(
            &signal[t..2 * t],
            stream_req_seed(sid, 1),
            0,
            s,
        );
        assert_ne!(
            whole[1].samples, want1.samples,
            "streaming must carry hidden state across beats"
        );

        let beat0: Vec<f32> = signal[..t].to_vec();
        for (ci, chunks) in [
            vec![3 * t],
            vec![5, 40, 27],
            vec![30, 30, 12],
            vec![t, t, t],
            vec![1; 3 * t],
        ]
        .iter()
        .enumerate()
        {
            let mut acc = Accelerator::new(&cfg, &params, reuse, 9);
            if ci == 2 {
                // Interleaved variant: the engines serve unrelated
                // one-shot traffic between chunks (worker reality).
                acc.set_mask_bank(Some(Arc::new(MaskBank::new(1 << 20))));
            }
            let mut st = acc.open_stream(sid, 0, s);
            let mut got = Vec::new();
            let mut off = 0;
            for &c in chunks.iter() {
                got.extend(
                    acc.predict_stream(&mut st, &signal[off..off + c])
                        .unwrap(),
                );
                off += c;
                if ci == 2 {
                    let _ = acc.predict_seeded(&beat0, 12345, 0, 4);
                }
            }
            assert_eq!(off, signal.len(), "chunking {ci} covers signal");
            assert_eq!(got.len(), whole.len());
            for (b, (g, w)) in got.iter().zip(&whole).enumerate() {
                assert_eq!(
                    g.samples, w.samples,
                    "chunking {ci}, beat {b} drifted from continuous pass"
                );
            }
        }
    }

    /// MC-shard invariance mid-stream: disjoint lane ranges held by
    /// separate accelerators (fleet engines), each resuming its own
    /// [`StreamState`], concatenate per beat to exactly the whole-range
    /// decisions — lane `k`'s trajectory is a pure function of
    /// `(design, session, beats, k)`, independent of engine count.
    #[test]
    fn stream_mc_shards_concatenate_to_whole_mid_stream() {
        let (cfg, params, signal) = stream_fixture();
        let reuse = ReuseFactors::new(1, 1, 1);
        let (s, sid) = (8usize, 0x1111u64);
        let mut one = Accelerator::new(&cfg, &params, reuse, 9);
        let mut st = one.open_stream(sid, 0, s);
        let whole = one.predict_stream(&mut st, &signal).unwrap();

        let ranges = [(0usize, 3usize), (3, 3), (6, 2)];
        let mut engines: Vec<(Accelerator, StreamState)> = ranges
            .iter()
            .map(|&(start, count)| {
                let a = Accelerator::new(&cfg, &params, reuse, 9);
                let st = a.open_stream(sid, start, count);
                (a, st)
            })
            .collect();
        let mut merged: Vec<Vec<f32>> = Vec::new();
        let mut off = 0;
        for &c in &[10usize, 30, 32] {
            let chunk = &signal[off..off + c];
            off += c;
            let mut per_engine: Vec<Vec<McOutput>> = Vec::new();
            for (a, st) in engines.iter_mut() {
                per_engine.push(a.predict_stream(st, chunk).unwrap());
            }
            let beats = per_engine[0].len();
            for outs in &per_engine {
                assert_eq!(outs.len(), beats, "shards stay in lockstep");
            }
            for b in 0..beats {
                let mut row = Vec::new();
                for outs in &per_engine {
                    row.extend(outs[b].samples.iter().copied());
                }
                merged.push(row);
            }
        }
        assert_eq!(merged.len(), whole.len());
        for (b, (m, w)) in merged.iter().zip(&whole).enumerate() {
            assert_eq!(m, &w.samples, "beat {b}: shard union != whole");
        }
    }

    /// The perf claim itself: a resumed chunk costs
    /// `chunk_timesteps x layers x lanes` recurrent lane-steps —
    /// independent of how much history the session has — while
    /// reaching the same decision one-shot costs the full history
    /// every time.
    #[test]
    fn resumed_chunks_cost_o_chunk_not_o_history() {
        let (cfg, params, signal) = stream_fixture();
        let reuse = ReuseFactors::new(1, 1, 1);
        let (s, sid, nl, t) = (6usize, 0x2222u64, cfg.nl, cfg.seq_len);
        let mut acc = Accelerator::new(&cfg, &params, reuse, 9);
        let mut st = acc.open_stream(sid, 0, s);
        // Two beats of history.
        acc.predict_stream(&mut st, &signal[..2 * t]).unwrap();
        // A resumed half-beat chunk: exactly O(chunk) lane-steps.
        let before = acc.lane_steps();
        let chunk = 12;
        acc.predict_stream(&mut st, &signal[2 * t..2 * t + chunk])
            .unwrap();
        assert_eq!(
            acc.lane_steps() - before,
            (chunk * nl * s) as u64,
            "resumed chunk must not recompute history"
        );
        // The one-shot shape of the same decision point pays the whole
        // history (2 beats + chunk) — the cost this PR removes.
        let replay_cost = ((2 * t + chunk) * nl * s) as u64;
        assert!((chunk * nl * s) as u64 * 5 < replay_cost);
        // And the meter also covers the one-shot path (same units).
        let b2 = acc.lane_steps();
        acc.predict_seeded(&signal[..t], 7, 0, s);
        assert_eq!(acc.lane_steps() - b2, (t * nl * s) as u64);
    }

    /// Eviction → replay equivalence at the accelerator level: a
    /// session whose resident lanes were dropped mid-stream (mid-beat,
    /// even) is rebuilt by replaying its history through a fresh
    /// [`StreamState`], lands bit-identical state, and continues
    /// bit-identically — the session table's transparent-rebuild
    /// contract.
    #[test]
    fn evicted_state_rebuilt_by_replay_is_bitwise_identical() {
        let (cfg, params, signal) = stream_fixture();
        let reuse = ReuseFactors::new(1, 1, 1);
        let (s, sid, t) = (5usize, 0x3333u64, cfg.seq_len);
        let split = 2 * t + 7; // mid-beat eviction point
        let mut resident = Accelerator::new(&cfg, &params, reuse, 9);
        let mut st_resident = resident.open_stream(sid, 0, s);
        let mut want =
            resident.predict_stream(&mut st_resident, &signal[..split]).unwrap();
        want.extend(
            resident.predict_stream(&mut st_resident, &signal[split..]).unwrap(),
        );

        // "Evict": drop the state entirely; rebuild by replaying the
        // consumed history into a fresh stream, then continue.
        let mut rebuilt = Accelerator::new(&cfg, &params, reuse, 9);
        let mut st1 = rebuilt.open_stream(sid, 0, s);
        let replayed =
            rebuilt.predict_stream(&mut st1, &signal[..split]).unwrap();
        let mut st2 = rebuilt.open_stream(sid, 0, s);
        let replayed2 =
            rebuilt.predict_stream(&mut st2, &signal[..split]).unwrap();
        assert_eq!(st1, st2, "replay lands bit-identical state");
        assert_eq!(replayed.len(), replayed2.len());
        for (a, b) in replayed.iter().zip(&replayed2) {
            assert_eq!(a.samples, b.samples, "replay decisions agree");
        }
        let tail =
            rebuilt.predict_stream(&mut st2, &signal[split..]).unwrap();
        let got: Vec<&McOutput> = replayed.iter().chain(&tail).collect();
        assert_eq!(got.len(), want.len());
        for (b, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.samples, w.samples, "beat {b} after rebuild");
        }
    }

    /// Typed streaming failures: anomaly designs are rejected, ragged
    /// chunks are rejected, and state opened on a different design
    /// shape is rejected.
    #[test]
    fn stream_rejects_unsupported_shapes() {
        let mut an = ArchConfig::new(Task::Anomaly, 8, 1, "Y");
        an.seq_len = 24;
        let an_params = Params::init(&an, &mut Rng::new(1));
        let mut anomaly = Accelerator::new(
            &an,
            &an_params,
            ReuseFactors::new(1, 1, 1),
            3,
        );
        let mut st = anomaly.open_stream(1, 0, 2);
        assert_eq!(
            anomaly.predict_stream(&mut st, &[0.0; 24]).unwrap_err(),
            StreamError::UnsupportedTask
        );

        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = 12;
        cfg.input_dim = 2;
        let params = Params::init(&cfg, &mut Rng::new(1));
        let mut acc = Accelerator::new(
            &cfg,
            &params,
            ReuseFactors::new(1, 1, 1),
            3,
        );
        let mut st = acc.open_stream(1, 0, 2);
        assert_eq!(
            acc.predict_stream(&mut st, &[0.0; 5]).unwrap_err(),
            StreamError::RaggedChunk { len: 5, idim: 2 },
        );

        let mut other_cfg = ArchConfig::new(Task::Classify, 16, 1, "Y");
        other_cfg.seq_len = 12;
        let other_params = Params::init(&other_cfg, &mut Rng::new(1));
        let other = Accelerator::new(
            &other_cfg,
            &other_params,
            ReuseFactors::new(1, 1, 1),
            3,
        );
        let mut foreign = other.open_stream(1, 0, 2);
        assert_eq!(
            acc.predict_stream(&mut foreign, &[0.0; 4]).unwrap_err(),
            StreamError::ShapeMismatch
        );

        // Degenerate inputs are fine: zero lanes track the schedule,
        // zero timesteps are a no-op.
        let mut empty = acc.open_stream(1, 3, 0);
        let outs = acc.predict_stream(&mut empty, &[0.0; 24]).unwrap();
        assert_eq!(outs.len(), 1, "one (empty) decision per beat");
        assert_eq!(outs[0].s, 0);
        assert_eq!(empty.beats_done, 1);
        let mut st = acc.open_stream(1, 0, 2);
        assert!(acc.predict_stream(&mut st, &[]).unwrap().is_empty());
    }

    #[test]
    fn resource_model_within_2_percent_of_synthesis() {
        // The Table III claim: the analytic DSP model is >= 98% accurate
        // against the synthesised design.
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let params = Params::init(&cfg, &mut Rng::new(0));
        let acc = Accelerator::new(
            &cfg,
            &params,
            ReuseFactors::new(12, 1, 1),
            0,
        );
        let syn = acc.resources_synthesized().dsps;
        let est = acc.resources_estimated().dsps;
        let err = (syn - est).abs() / syn;
        assert!(err < 0.02, "model error {err}: syn {syn} est {est}");
    }
}
