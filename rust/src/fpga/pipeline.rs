//! Cycle-accurate timing simulation of the streaming pipeline — the
//! "measured" latency source that validates the Sec. IV-C analytic model.
//!
//! Event model: each LSTM engine accepts one timestep token every II
//! cycles and emits its hidden state IL cycles after acceptance. A token
//! for (pass p, layer l, timestep t) can start when
//!   * the engine is free (II spacing),
//!   * the producing layer has emitted h_t (timestep pipelining, Fig. 5),
//!   * the engine's own h_{t-1} exists (the recurrent dependency),
//!   * the pass's Bernoulli masks are ready (pre-sampling overlap, Fig. 4),
//!   * for decoder layers: the encoder finished the whole sequence (the
//!     bottleneck is the *last* hidden state).
//!
//! The simulation is exact over these constraints, which is what an HLS
//! schedule with ap_ctrl pipelining realises; comparing it against the
//! closed-form `II*T + (IL-II)*NL` reproduces the paper's ~2% model-error
//! ablation.

use crate::config::{ArchConfig, Task};
use crate::hwmodel::latency::LatencyModel;
use crate::hwmodel::resource::ReuseFactors;
use crate::lfsr::BernoulliSampler;

/// Result of simulating a workload.
#[derive(Debug, Clone, Copy)]
pub struct PipelineReport {
    /// Total cycles until the last output is produced.
    pub cycles: u64,
    /// Cycles the analytic model predicts for the same workload.
    pub model_cycles: u64,
    /// |sim - model| / sim.
    pub model_error: f64,
}

/// Timing-only simulator (numerics live in `accel`). Format-independent
/// at fixed reuse: precision reaches timing through the lower reuse the
/// constraint solver finds at narrow formats (`docs/quantization.md`).
pub struct PipelineSim {
    cfg: ArchConfig,
    reuse: ReuseFactors,
    /// Per-LSTM-layer (II, IL).
    timing: Vec<(u64, u64)>,
}

impl PipelineSim {
    pub fn new(cfg: &ArchConfig, reuse: ReuseFactors) -> Self {
        // The paper balances IIs across cascaded layers (Sec. III-A), so
        // every layer runs at the design II; IL keeps per-layer depth.
        let design = LatencyModel::design_timing(cfg, &reuse);
        let timing = cfg
            .lstm_dims()
            .iter()
            .map(|&(i, h)| {
                let t = LatencyModel::lstm_timing(i, h, &reuse);
                (design.ii, t.il.max(design.ii))
            })
            .collect();
        Self { cfg: cfg.clone(), reuse, timing }
    }

    /// Simulate `batch` beats x `s` MC passes streamed through the design.
    pub fn simulate(&self, batch: usize, s: usize) -> PipelineReport {
        let t = self.cfg.seq_len as u64;
        let nl = self.cfg.nl;
        let layers = self.cfg.num_lstm_layers();
        let passes = (batch * s) as u64;

        // Bernoulli pre-sampling: masks for pass p must be ready before
        // its first token. Sampler runs one bit/cycle, overlapped with
        // the previous pass (Fig. 4); it binds only if mask_bits > II*T.
        let mask_bits: u64 = self
            .cfg
            .lstm_dims()
            .iter()
            .enumerate()
            .filter(|(l, _)| self.cfg.bayes[*l])
            .map(|(_, &(i, h))| {
                BernoulliSampler::cycles_for(4 * (i + h)) as u64
            })
            .max()
            .unwrap_or(0);

        // emit[l][ti] = cycle when layer l emits h_ti for the current
        // pass. We iterate passes, carrying each engine's next-free time.
        let mut engine_free = vec![0u64; layers];
        let mut dense_free = 0u64;
        let mut last_output = 0u64;
        let mut masks_ready = 0u64;

        let mut emit_prev: Vec<u64>;
        for _p in 0..passes {
            // Masks for this pass were pre-sampled during the previous
            // pass's compute; they are ready `mask_bits` cycles after the
            // previous pass's sampling started.
            let pass_gate = masks_ready;
            masks_ready = pass_gate + mask_bits.max(1);

            // Encoder layers. The recurrent h_{t-1} dependency binds at
            // the *short feedback path* — II cycles after the previous
            // step started — not at the full output depth IL: the paper's
            // II balancing exists precisely to make the h feedback close
            // within II (else the timestep loop II would be unachievable).
            // IL shows up only as inter-layer skew (pipeline fill).
            emit_prev = Vec::new();
            for l in 0..nl {
                let (ii, il) = self.timing[l];
                let mut emit = vec![0u64; t as usize];
                let mut prev_accept = 0u64;
                for ti in 0..t as usize {
                    let input_ready = if l == 0 {
                        pass_gate // DMA stream
                    } else {
                        emit_prev[ti]
                    };
                    // Engine spacing + recurrence: both close at II.
                    let engine_ready = if ti == 0 {
                        engine_free[l]
                    } else {
                        prev_accept + ii
                    };
                    let start = input_ready.max(engine_ready);
                    prev_accept = start;
                    emit[ti] = start + il;
                }
                engine_free[l] = prev_accept + ii;
                emit_prev = emit;
            }

            match self.cfg.task {
                Task::Anomaly => {
                    // Decoder waits for the full bottleneck.
                    let bottleneck_done = emit_prev[t as usize - 1];
                    for l in nl..layers {
                        let (ii, il) = self.timing[l];
                        let mut emit = vec![0u64; t as usize];
                        let mut prev_accept = 0u64;
                        for ti in 0..t as usize {
                            let input_ready = if l == nl {
                                bottleneck_done // cached embedding
                            } else {
                                emit_prev[ti]
                            };
                            let engine_ready = if ti == 0 {
                                engine_free[l]
                            } else {
                                prev_accept + ii
                            };
                            let start = input_ready.max(engine_ready);
                            prev_accept = start;
                            emit[ti] = start + il;
                        }
                        engine_free[l] = prev_accept + ii;
                        emit_prev = emit;
                    }
                    // Temporal dense: one output per timestep, II = R_d.
                    let rd = self.reuse.rd as u64;
                    for ti in 0..t as usize {
                        let start = emit_prev[ti].max(dense_free);
                        dense_free = start + rd;
                        last_output = last_output.max(start + rd + 2);
                    }
                }
                Task::Classify => {
                    let rd = self.reuse.rd as u64;
                    let start = emit_prev[t as usize - 1].max(dense_free);
                    dense_free = start + rd;
                    last_output = last_output.max(start + rd + 2);
                }
            }
        }

        let model_cycles =
            LatencyModel::batch_cycles(&self.cfg, &self.reuse, batch, s);
        let cycles = last_output;
        let model_error =
            (cycles as f64 - model_cycles as f64).abs() / cycles as f64;
        PipelineReport { cycles, model_cycles, model_error }
    }

    /// Simulated milliseconds at the given clock.
    pub fn simulate_ms(&self, batch: usize, s: usize, clock_hz: f64) -> f64 {
        self.simulate(batch, s).cycles as f64 / clock_hz * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::ZC706;

    #[test]
    fn classifier_single_pass_close_to_model() {
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let sim = PipelineSim::new(&cfg, ReuseFactors::new(12, 1, 1));
        let rep = sim.simulate(1, 1);
        assert!(
            rep.model_error < 0.05,
            "sim {} vs model {} ({:.1}%)",
            rep.cycles,
            rep.model_cycles,
            rep.model_error * 100.0
        );
    }

    #[test]
    fn batch_workload_model_error_under_3_percent() {
        // The paper's ablation: analytic prediction within 2.26% / 2.13%
        // of measurement for the two best designs at batch 50, S=30.
        let ae = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        let sim_ae = PipelineSim::new(&ae, ReuseFactors::new(16, 5, 16));
        let rep_ae = sim_ae.simulate(50, 30);
        assert!(
            rep_ae.model_error < 0.03,
            "AE error {:.2}%",
            rep_ae.model_error * 100.0
        );

        let cls = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let sim_c = PipelineSim::new(&cls, ReuseFactors::new(12, 1, 1));
        let rep_c = sim_c.simulate(50, 30);
        assert!(
            rep_c.model_error < 0.03,
            "cls error {:.2}%",
            rep_c.model_error * 100.0
        );
    }

    #[test]
    fn paper_table4_classifier_latency_scale() {
        // Classifier, batch 50, S=30, Rx=12: paper measures 25.23 ms.
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let sim = PipelineSim::new(&cfg, ReuseFactors::new(12, 1, 1));
        let ms = sim.simulate_ms(50, 30, ZC706.clock_hz);
        assert!(
            (ms - 25.23).abs() / 25.23 < 0.06,
            "simulated {ms} ms vs paper 25.23 ms"
        );
    }

    #[test]
    fn timestep_pipelining_hides_depth() {
        // NL=3 must cost barely more than NL=1 for one pass (Table VI).
        let c1 = ArchConfig::new(Task::Classify, 8, 1, "N");
        let c3 = ArchConfig::new(Task::Classify, 8, 3, "NNN");
        let r = ReuseFactors::new(12, 1, 1);
        let l1 = PipelineSim::new(&c1, r).simulate(1, 1).cycles;
        let l3 = PipelineSim::new(&c3, r).simulate(1, 1).cycles;
        assert!(l3 > l1);
        assert!((l3 - l1) < l1 / 10, "{l1} vs {l3}");
    }

    #[test]
    fn decoder_serialises_autoencoder() {
        let ae = ArchConfig::new(Task::Anomaly, 8, 1, "NN");
        let cls = ArchConfig::new(Task::Classify, 8, 1, "N");
        let r = ReuseFactors::new(4, 4, 4);
        let la = PipelineSim::new(&ae, r).simulate(1, 1).cycles;
        let lc = PipelineSim::new(&cls, r).simulate(1, 1).cycles;
        let ratio = la as f64 / lc as f64;
        assert!(
            (ratio - 2.0).abs() < 0.2,
            "AE should be ~2x the classifier: {ratio}"
        );
    }

    #[test]
    fn sampling_overlap_is_free_at_realistic_ii() {
        // Mask bits (4*(I+H) per Bayesian layer) stream at 1 bit/cycle and
        // hide under II*T compute; Bayesian and pointwise twins at the
        // same reuse must have near-identical cycles.
        let b = ArchConfig::new(Task::Classify, 8, 3, "YYY");
        let p = ArchConfig::new(Task::Classify, 8, 3, "NNN");
        let r = ReuseFactors::new(12, 1, 1);
        let cb = PipelineSim::new(&b, r).simulate(4, 8).cycles;
        let cp = PipelineSim::new(&p, r).simulate(4, 8).cycles;
        let rel = (cb as f64 - cp as f64).abs() / cp as f64;
        assert!(rel < 0.02, "sampling must overlap compute: {cb} vs {cp}");
    }

    /// Property sweep: simulated cycles are monotone in batch, S and
    /// reuse, and the analytic model never diverges past a few percent
    /// at steady state.
    #[test]
    fn monotonicity_properties_random() {
        use crate::rng::Rng;
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let h = [8usize, 16][rng.below(2)];
            let nl = 1 + rng.below(3);
            let pattern: String =
                (0..nl).map(|_| if rng.bernoulli(0.5) { 'Y' } else { 'N' })
                    .collect();
            let cfg = ArchConfig::new(Task::Classify, h, nl, &pattern);
            let r1 = 1 + rng.below(8);
            let reuse = ReuseFactors::new(r1, r1, 1);
            let sim = PipelineSim::new(&cfg, reuse);
            let a = sim.simulate(2, 4).cycles;
            let b = sim.simulate(4, 4).cycles;
            let c = sim.simulate(4, 8).cycles;
            assert!(b > a, "more beats, more cycles");
            assert!(c > b, "more samples, more cycles");
            let slower =
                PipelineSim::new(&cfg, ReuseFactors::new(r1 * 2, r1 * 2, 1));
            assert!(
                slower.simulate(2, 4).cycles > a,
                "higher reuse, more cycles"
            );
            let steady = sim.simulate(16, 8);
            assert!(
                steady.model_error < 0.03,
                "steady-state model error {:.3}",
                steady.model_error
            );
        }
    }

    /// Precision reaches the cycle simulator through the lower reuse
    /// the constraint solver finds at q8 (packed DSPs): the q8 design
    /// simulates materially faster, and the analytic model still
    /// tracks it at the lower reuse.
    #[test]
    fn q8_reuse_simulates_faster_and_model_still_tracks() {
        use crate::dse::space::reuse_search_q;
        use crate::fixedpoint::Precision;
        let cfg = ArchConfig::new(Task::Classify, 32, 3, "YYY");
        let r16 = reuse_search_q(&cfg, &ZC706, &Precision::q16()).unwrap();
        let r8 = reuse_search_q(&cfg, &ZC706, &Precision::q8()).unwrap();
        let q16 = PipelineSim::new(&cfg, r16).simulate(50, 30);
        let q8 = PipelineSim::new(&cfg, r8).simulate(50, 30);
        assert!(
            (q8.cycles as f64) < 0.75 * q16.cycles as f64,
            "q8 {} !<< q16 {}",
            q8.cycles,
            q16.cycles
        );
        assert!(
            q8.model_error < 0.03,
            "q8 model error {:.2}%",
            q8.model_error * 100.0
        );
    }

    #[test]
    fn higher_reuse_slower_but_smaller() {
        let cfg = ArchConfig::new(Task::Classify, 16, 2, "NN");
        let fast = PipelineSim::new(&cfg, ReuseFactors::new(1, 1, 1))
            .simulate(8, 4)
            .cycles;
        let slow = PipelineSim::new(&cfg, ReuseFactors::new(16, 16, 4))
            .simulate(8, 4)
            .cycles;
        assert!(slow > 8 * fast, "reuse must cost cycles: {fast} vs {slow}");
    }
}
