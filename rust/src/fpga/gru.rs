//! Fixed-point GRU engine — the paper's "similar design logic can be used
//! for other recurrent units such as the gated recurrent unit" (Sec.
//! III-A) made concrete. Three gate MVM pairs instead of four, no 32-bit
//! cell path (the GRU state is bounded by tanh, so the 16-bit path
//! suffices), and an extra elementwise multiplier for r*(Wh_n h). The
//! ablation bench compares DSP/latency/accuracy against the LSTM engine.

use crate::fixedpoint::{ActLut, Fx16, MacAcc};
use crate::nn::gru::GRU_GATES;
use crate::tensor::Tensor;

use super::engine::MvmUnit;

pub struct GruEngine {
    pub idim: usize,
    pub hdim: usize,
    pub mvm_x: Vec<MvmUnit>,
    pub mvm_h: Vec<MvmUnit>,
    pub bias: Vec<Fx16>,
    pub bayesian: bool,
    sigmoid: ActLut,
    tanh: ActLut,
    pub zx: Vec<Fx16>,
    pub zh: Vec<Fx16>,
    h: Vec<Fx16>,
    masked: Vec<Fx16>,
    acc: Vec<MacAcc>,
    xterm: Vec<Fx16>,
    hterm: Vec<Fx16>,
}

impl GruEngine {
    /// wx `[3, I, H]`, wh `[3, H, H]`, b `[3, H]` (gate order r, z, n).
    pub fn new(
        wx: &Tensor,
        wh: &Tensor,
        b: &Tensor,
        rx: usize,
        rh: usize,
        bayesian: bool,
    ) -> Self {
        let idim = wx.shape[1];
        let hdim = wx.shape[2];
        let mvm_x = (0..GRU_GATES)
            .map(|g| {
                MvmUnit::new(
                    &wx.data[g * idim * hdim..(g + 1) * idim * hdim],
                    idim,
                    hdim,
                    rx,
                )
            })
            .collect();
        let mvm_h = (0..GRU_GATES)
            .map(|g| {
                MvmUnit::new(
                    &wh.data[g * hdim * hdim..(g + 1) * hdim * hdim],
                    hdim,
                    hdim,
                    rh,
                )
            })
            .collect();
        Self {
            idim,
            hdim,
            mvm_x,
            mvm_h,
            bias: b.data.iter().map(|&v| Fx16::from_f32(v)).collect(),
            bayesian,
            sigmoid: ActLut::sigmoid(),
            tanh: ActLut::tanh(),
            zx: vec![Fx16::ONE; GRU_GATES * idim],
            zh: vec![Fx16::ONE; GRU_GATES * hdim],
            h: vec![Fx16::ZERO; hdim],
            masked: vec![Fx16::ZERO; idim.max(hdim)],
            acc: vec![MacAcc::new(); hdim],
            xterm: vec![Fx16::ZERO; GRU_GATES * hdim],
            hterm: vec![Fx16::ZERO; GRU_GATES * hdim],
        }
    }

    pub fn set_masks(&mut self, zx: &[f32], zh: &[f32]) {
        for (d, &s) in self.zx.iter_mut().zip(zx) {
            *d = if s == 0.0 { Fx16::ZERO } else { Fx16::ONE };
        }
        for (d, &s) in self.zh.iter_mut().zip(zh) {
            *d = if s == 0.0 { Fx16::ZERO } else { Fx16::ONE };
        }
    }

    pub fn reset(&mut self) {
        self.h.fill(Fx16::ZERO);
    }

    pub fn step(&mut self, x: &[Fx16]) -> &[Fx16] {
        let hdim = self.hdim;
        // x-path terms per gate: (x*zx_g) Wx_g + b_g.
        for g in 0..GRU_GATES {
            for a in self.acc.iter_mut() {
                *a = MacAcc::new();
            }
            for i in 0..self.idim {
                self.masked[i] = if self.zx[g * self.idim + i].0 == 0 {
                    Fx16::ZERO
                } else {
                    x[i]
                };
            }
            self.mvm_x[g].mac_into(&self.masked[..self.idim], &mut self.acc);
            for k in 0..hdim {
                self.xterm[g * hdim + k] =
                    self.acc[k].finish(self.bias[g * hdim + k]);
            }
        }
        // h-path terms per gate: (h*zh_g) Wh_g (bias already in xterm).
        for g in 0..GRU_GATES {
            for a in self.acc.iter_mut() {
                *a = MacAcc::new();
            }
            for j in 0..hdim {
                self.masked[j] = if self.zh[g * hdim + j].0 == 0 {
                    Fx16::ZERO
                } else {
                    self.h[j]
                };
            }
            self.mvm_h[g].mac_into(&self.masked[..hdim], &mut self.acc);
            for k in 0..hdim {
                self.hterm[g * hdim + k] = self.acc[k].finish(Fx16::ZERO);
            }
        }
        // Tail: r, z sigmoid on (xterm+hterm); n = tanh(xterm_n + r*hterm_n);
        // h = (1-z) n + z h_prev.
        for k in 0..hdim {
            let r = self.sigmoid.eval(
                self.xterm[k].saturating_add(self.hterm[k]),
            );
            let z = self.sigmoid.eval(
                self.xterm[hdim + k].saturating_add(self.hterm[hdim + k]),
            );
            let n = self.tanh.eval(
                self.xterm[2 * hdim + k]
                    .saturating_add(r.saturating_mul(self.hterm[2 * hdim + k])),
            );
            let one_minus_z = Fx16::ONE.saturating_add(Fx16(-z.0));
            self.h[k] = one_minus_z
                .saturating_mul(n)
                .saturating_add(z.saturating_mul(self.h[k]));
        }
        &self.h
    }

    pub fn hidden(&self) -> &[Fx16] {
        &self.h
    }

    /// DSPs: 3 gate MVM pairs + 3H tail multipliers (r*hn, (1-z)*n, z*h),
    /// all on the 16-bit path (no 2-DSP 32-bit c multiplier).
    pub fn dsps_synthesized(&self) -> u64 {
        let mvms: u64 = self
            .mvm_x
            .iter()
            .chain(self.mvm_h.iter())
            .map(MvmUnit::dsps_synthesized)
            .sum();
        mvms + 3 * self.hdim as u64
    }

    pub fn ii(&self) -> u64 {
        self.mvm_x[0].ii().max(self.mvm_h[0].ii())
    }

    pub fn mask_bits(&self) -> usize {
        if self.bayesian {
            GRU_GATES * (self.idim + self.hdim)
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::gru::{self, GruLayer};
    use crate::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: &[usize], s: f64) -> Tensor {
        Tensor::from_fn(shape, |_| rng.normal_scaled(0.0, s) as f32)
    }

    #[test]
    fn tracks_float_gru_over_sequence() {
        let mut rng = Rng::new(3);
        let (idim, hdim, t) = (2, 6, 16);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.3);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.3);
        let b = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let xs: Vec<f32> =
            (0..t * idim).map(|_| rng.normal() as f32 * 0.8).collect();
        // Float reference.
        let layer = GruLayer { wx: &wx, wh: &wh, b: &b };
        let zx = Tensor::ones(&[1, GRU_GATES, idim]);
        let zh = Tensor::ones(&[1, GRU_GATES, hdim]);
        let cache = gru::forward(&layer, &xs, 1, t, &zx, &zh);
        // Fixed-point engine.
        let mut e = GruEngine::new(&wx, &wh, &b, 1, 1, false);
        let mut last = vec![];
        for ti in 0..t {
            let xq: Vec<Fx16> = xs[ti * idim..(ti + 1) * idim]
                .iter()
                .map(|&v| Fx16::from_f32(v))
                .collect();
            last = e.step(&xq).to_vec();
        }
        for k in 0..hdim {
            let got = last[k].to_f32();
            let want = cache.last_h()[k];
            assert!(
                (got - want).abs() < 0.06,
                "h[{k}]: fx {got} vs float {want}"
            );
        }
    }

    #[test]
    fn gru_state_bounded() {
        let mut rng = Rng::new(9);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, 1, 4], 1.0);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, 4, 4], 1.0);
        let b = rand_tensor(&mut rng, &[GRU_GATES, 4], 0.5);
        let mut e = GruEngine::new(&wx, &wh, &b, 1, 1, false);
        for i in 0..100 {
            let h = e.step(&[Fx16::from_f32((i as f32 * 0.7).sin() * 3.0)]);
            assert!(h.iter().all(|v| v.to_f32().abs() <= 1.01));
        }
    }

    #[test]
    fn gru_cheaper_than_lstm_in_dsps() {
        // 3 gates + 16-bit tail vs 4 gates + 32-bit tail: the GRU engine
        // must synthesise to fewer DSPs at the same (I, H, R).
        use crate::config::GATES;
        use crate::fpga::engine::LstmEngine;
        let mut rng = Rng::new(0);
        let (idim, hdim) = (8, 8);
        let gwx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.3);
        let gwh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.3);
        let gb = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let lwx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.3);
        let lwh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.3);
        let lb = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let g = GruEngine::new(&gwx, &gwh, &gb, 2, 2, true);
        let l = LstmEngine::new(&lwx, &lwh, &lb, 2, 2, true);
        assert!(g.dsps_synthesized() < l.dsps_synthesized());
        assert_eq!(g.ii(), l.ii());
        assert!(g.mask_bits() < l.mask_bits());
    }

    #[test]
    fn masks_gate_input() {
        let mut rng = Rng::new(5);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, 2, 4], 0.5);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, 4, 4], 0.5);
        let b = Tensor::zeros(&[GRU_GATES, 4]);
        let mut e = GruEngine::new(&wx, &wh, &b, 1, 1, true);
        e.set_masks(&vec![0.0; GRU_GATES * 2], &vec![0.0; GRU_GATES * 4]);
        let h1 = e.step(&[Fx16::from_f32(1.0), Fx16::from_f32(-1.0)]).to_vec();
        let mut e2 = GruEngine::new(&wx, &wh, &b, 1, 1, true);
        let h2 = e2.step(&[Fx16::ZERO, Fx16::ZERO]).to_vec();
        assert_eq!(
            h1.iter().map(|v| v.0).collect::<Vec<_>>(),
            h2.iter().map(|v| v.0).collect::<Vec<_>>()
        );
    }
}
