//! Fixed-point GRU engine — the paper's "similar design logic can be used
//! for other recurrent units such as the gated recurrent unit" (Sec.
//! III-A) made concrete. Three gate MVM pairs instead of four, no 32-bit
//! cell path (the GRU state is bounded by tanh, so the 16-bit path
//! suffices), and an extra elementwise multiplier for r*(Wh_n h). The
//! ablation bench compares DSP/latency/accuracy against the LSTM engine.
//!
//! Like the LSTM engine, the GRU is precision-parametric
//! ([`GruEngine::with_format`], `docs/quantization.md`): `new` builds
//! the paper's Q6.10 instance — bit-identical to the pre-parametric
//! implementation, pinned by the legacy-op oracle test below — and
//! `with_format` opens the 8/12-bit paths so `--precision` applies to
//! GRU designs too. DX masks are packed [`BitPlanes`] fused into the
//! MVMs through the shared kernel layer (no masked input copy), and the
//! kernel backend is switchable per engine (`set_backend`).

use crate::fixedpoint::{ActLut, Fx16, MacAcc, QFormat, QuantSpec};
use crate::kernels::{BitPlanes, KernelBackend, MaskRef};
use crate::nn::gru::GRU_GATES;
use crate::tensor::Tensor;

use super::engine::MvmUnit;

pub struct GruEngine {
    pub idim: usize,
    pub hdim: usize,
    pub mvm_x: Vec<MvmUnit>,
    pub mvm_h: Vec<MvmUnit>,
    pub bias: Vec<Fx16>,
    pub bayesian: bool,
    /// Activation format this engine is quantised in (single-width —
    /// no widened cell path in a GRU).
    pub spec: QuantSpec,
    sigmoid: ActLut,
    tanh: ActLut,
    /// 1.0 on the activation lattice (the `(1 - z)` constant).
    one: Fx16,
    /// DX masks, `[1][GRU_GATES * dim]` bitplanes.
    pub zx: BitPlanes,
    pub zh: BitPlanes,
    h: Vec<Fx16>,
    acc: Vec<MacAcc>,
    xterm: Vec<Fx16>,
    hterm: Vec<Fx16>,
}

impl GruEngine {
    /// wx `[3, I, H]`, wh `[3, H, H]`, b `[3, H]` (gate order r, z, n) —
    /// the paper's Q6.10 instance.
    pub fn new(
        wx: &Tensor,
        wh: &Tensor,
        b: &Tensor,
        rx: usize,
        rh: usize,
        bayesian: bool,
    ) -> Self {
        Self::with_format(wx, wh, b, rx, rh, bayesian, QuantSpec::q16())
    }

    /// Build at an explicit format (the `--precision` path for GRU
    /// designs). At `QuantSpec::q16()` this is bit-identical to the
    /// legacy constructor (oracle test below).
    pub fn with_format(
        wx: &Tensor,
        wh: &Tensor,
        b: &Tensor,
        rx: usize,
        rh: usize,
        bayesian: bool,
        spec: QuantSpec,
    ) -> Self {
        let idim = wx.shape[1];
        let hdim = wx.shape[2];
        let fmt = spec.act;
        let mvm_x = (0..GRU_GATES)
            .map(|g| {
                MvmUnit::with_format(
                    &wx.data[g * idim * hdim..(g + 1) * idim * hdim],
                    idim,
                    hdim,
                    rx,
                    fmt,
                )
            })
            .collect();
        let mvm_h = (0..GRU_GATES)
            .map(|g| {
                MvmUnit::with_format(
                    &wh.data[g * hdim * hdim..(g + 1) * hdim * hdim],
                    hdim,
                    hdim,
                    rh,
                    fmt,
                )
            })
            .collect();
        Self {
            idim,
            hdim,
            mvm_x,
            mvm_h,
            bias: b.data.iter().map(|&v| fmt.quantize(v)).collect(),
            bayesian,
            spec,
            sigmoid: ActLut::sigmoid_fmt(fmt),
            tanh: ActLut::tanh_fmt(fmt),
            one: fmt.quantize(1.0),
            zx: BitPlanes::ones(1, GRU_GATES * idim),
            zh: BitPlanes::ones(1, GRU_GATES * hdim),
            h: vec![Fx16::ZERO; hdim],
            acc: vec![MacAcc::new(); hdim],
            xterm: vec![Fx16::ZERO; GRU_GATES * hdim],
            hterm: vec![Fx16::ZERO; GRU_GATES * hdim],
        }
    }

    /// The format lane data enters/leaves this engine in.
    pub fn act_format(&self) -> QFormat {
        self.spec.act
    }

    /// Switch every gate MVM to a kernel backend (bits unchanged).
    pub fn set_backend(&mut self, backend: KernelBackend) {
        for u in self.mvm_x.iter_mut().chain(self.mvm_h.iter_mut()) {
            u.set_backend(backend);
        }
    }

    pub fn set_masks(&mut self, zx: &[f32], zh: &[f32]) {
        debug_assert_eq!(zx.len(), GRU_GATES * self.idim);
        debug_assert_eq!(zh.len(), GRU_GATES * self.hdim);
        for (j, &s) in zx.iter().enumerate() {
            self.zx.set(0, j, s != 0.0);
        }
        for (j, &s) in zh.iter().enumerate() {
            self.zh.set(0, j, s != 0.0);
        }
    }

    pub fn reset(&mut self) {
        self.h.fill(Fx16::ZERO);
    }

    pub fn step(&mut self, x: &[Fx16]) -> &[Fx16] {
        let hdim = self.hdim;
        let fmt = self.spec.act;
        // x-path terms per gate: (x*zx_g) Wx_g + b_g — DX gating fused
        // into the MVM through the kernel layer (no masked copy).
        for g in 0..GRU_GATES {
            for a in self.acc.iter_mut() {
                *a = MacAcc::new();
            }
            self.mvm_x[g].mac_rows_masked(
                x,
                self.idim,
                MaskRef::Bits(self.zx.lanes(g * self.idim)),
                &mut self.acc,
                hdim,
                1,
            );
            for k in 0..hdim {
                self.xterm[g * hdim + k] = self.acc[k]
                    .finish_fmt(self.bias[g * hdim + k], fmt);
            }
        }
        // h-path terms per gate: (h*zh_g) Wh_g (bias already in xterm).
        for g in 0..GRU_GATES {
            for a in self.acc.iter_mut() {
                *a = MacAcc::new();
            }
            self.mvm_h[g].mac_rows_masked(
                &self.h,
                hdim,
                MaskRef::Bits(self.zh.lanes(g * hdim)),
                &mut self.acc,
                hdim,
                1,
            );
            for k in 0..hdim {
                self.hterm[g * hdim + k] =
                    self.acc[k].finish_fmt(Fx16::ZERO, fmt);
            }
        }
        // Tail: r, z sigmoid on (xterm+hterm); n = tanh(xterm_n + r*hterm_n);
        // h = (1-z) n + z h_prev — all at the engine's format rails.
        for k in 0..hdim {
            let r = self.sigmoid.eval(
                fmt.sat_add(self.xterm[k], self.hterm[k]),
            );
            let z = self.sigmoid.eval(
                fmt.sat_add(self.xterm[hdim + k], self.hterm[hdim + k]),
            );
            let n = self.tanh.eval(fmt.sat_add(
                self.xterm[2 * hdim + k],
                fmt.sat_mul(r, self.hterm[2 * hdim + k]),
            ));
            let one_minus_z = fmt.sat_add(self.one, Fx16(-z.0));
            self.h[k] = fmt.sat_add(
                fmt.sat_mul(one_minus_z, n),
                fmt.sat_mul(z, self.h[k]),
            );
        }
        &self.h
    }

    pub fn hidden(&self) -> &[Fx16] {
        &self.h
    }

    /// Snapshot the architectural state (h only — a GRU has no cell
    /// register) as packed words, 4 x i16 per u64, zero tail padding.
    /// The streaming save path for GRU designs.
    pub fn state_words(&self) -> Vec<u64> {
        let mut words = Vec::with_capacity(self.hdim.div_ceil(4));
        for chunk in self.h.chunks(4) {
            let mut w = 0u64;
            for (i, v) in chunk.iter().enumerate() {
                w |= ((v.0 as u16) as u64) << (16 * i);
            }
            words.push(w);
        }
        words
    }

    /// Restore from a [`GruEngine::state_words`] snapshot — bit-exact
    /// inverse of the save.
    pub fn set_state_words(&mut self, words: &[u64]) {
        assert_eq!(
            words.len(),
            self.hdim.div_ceil(4),
            "state shape mismatch"
        );
        for k in 0..self.hdim {
            self.h[k] =
                Fx16(((words[k / 4] >> (16 * (k % 4))) & 0xFFFF) as u16
                    as i16);
        }
    }

    /// DSPs: 3 gate MVM pairs + 3H tail multipliers (r*hn, (1-z)*n, z*h),
    /// all on the 16-bit path (no 2-DSP 32-bit c multiplier).
    pub fn dsps_synthesized(&self) -> u64 {
        let mvms: u64 = self
            .mvm_x
            .iter()
            .chain(self.mvm_h.iter())
            .map(MvmUnit::dsps_synthesized)
            .sum();
        mvms + 3 * self.hdim as u64
    }

    pub fn ii(&self) -> u64 {
        self.mvm_x[0].ii().max(self.mvm_h[0].ii())
    }

    pub fn mask_bits(&self) -> usize {
        if self.bayesian {
            GRU_GATES * (self.idim + self.hdim)
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::gru::{self, GruLayer};
    use crate::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: &[usize], s: f64) -> Tensor {
        Tensor::from_fn(shape, |_| rng.normal_scaled(0.0, s) as f32)
    }

    #[test]
    fn tracks_float_gru_over_sequence() {
        let mut rng = Rng::new(3);
        let (idim, hdim, t) = (2, 6, 16);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.3);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.3);
        let b = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let xs: Vec<f32> =
            (0..t * idim).map(|_| rng.normal() as f32 * 0.8).collect();
        // Float reference.
        let layer = GruLayer { wx: &wx, wh: &wh, b: &b };
        let zx = Tensor::ones(&[1, GRU_GATES, idim]);
        let zh = Tensor::ones(&[1, GRU_GATES, hdim]);
        let cache = gru::forward(&layer, &xs, 1, t, &zx, &zh);
        // Fixed-point engine.
        let mut e = GruEngine::new(&wx, &wh, &b, 1, 1, false);
        let mut last = vec![];
        for ti in 0..t {
            let xq: Vec<Fx16> = xs[ti * idim..(ti + 1) * idim]
                .iter()
                .map(|&v| Fx16::from_f32(v))
                .collect();
            last = e.step(&xq).to_vec();
        }
        for k in 0..hdim {
            let got = last[k].to_f32();
            let want = cache.last_h()[k];
            assert!(
                (got - want).abs() < 0.06,
                "h[{k}]: fx {got} vs float {want}"
            );
        }
    }

    /// GRU-level leg of the Q6.10 contract (ISSUE 5 satellite): the
    /// parametric engine at `QuantSpec::q16()` must reproduce, bit for
    /// bit, a from-scratch reference step written entirely in the
    /// frozen legacy `Fx16` ops and Q6.10 LUTs — the pre-parametric
    /// implementation, masked-copy semantics included.
    #[test]
    fn q16_gru_matches_legacy_op_oracle_bitwise() {
        let mut rng = Rng::new(19);
        let (idim, hdim, steps) = (3, 5, 8);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.4);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.4);
        let b = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let zx: Vec<f32> = (0..GRU_GATES * idim)
            .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
            .collect();
        let zh: Vec<f32> = (0..GRU_GATES * hdim)
            .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
            .collect();
        let xs: Vec<Fx16> = (0..steps * idim)
            .map(|_| Fx16::from_f32(rng.normal() as f32))
            .collect();

        let mut engine =
            GruEngine::with_format(&wx, &wh, &b, 1, 1, true, QuantSpec::q16());
        engine.set_masks(&zx, &zh);

        // Legacy oracle: Fx16::from_f32 quantisation, masked input
        // copies, ascending-row MACs, MacAcc::finish, tail with the
        // frozen saturating ops and legacy Q6.10 LUTs.
        let sigmoid = ActLut::sigmoid();
        let tanh = ActLut::tanh();
        let qw = |t: &Tensor| -> Vec<Fx16> {
            t.data.iter().map(|&v| Fx16::from_f32(v)).collect()
        };
        let (qwx, qwh, qb) = (qw(&wx), qw(&wh), qw(&b));
        let mut h = vec![Fx16::ZERO; hdim];
        for t in 0..steps {
            let x = &xs[t * idim..(t + 1) * idim];
            let mut xterm = vec![Fx16::ZERO; GRU_GATES * hdim];
            let mut hterm = vec![Fx16::ZERO; GRU_GATES * hdim];
            for g in 0..GRU_GATES {
                let mut acc = vec![MacAcc::new(); hdim];
                for (i, &xi) in x.iter().enumerate() {
                    let masked = if zx[g * idim + i] == 0.0 {
                        Fx16::ZERO
                    } else {
                        xi
                    };
                    if masked.0 == 0 {
                        continue;
                    }
                    for k in 0..hdim {
                        acc[k].mac(masked, qwx[(g * idim + i) * hdim + k]);
                    }
                }
                for k in 0..hdim {
                    xterm[g * hdim + k] = acc[k].finish(qb[g * hdim + k]);
                }
            }
            for g in 0..GRU_GATES {
                let mut acc = vec![MacAcc::new(); hdim];
                for (j, &hj) in h.iter().enumerate() {
                    let masked = if zh[g * hdim + j] == 0.0 {
                        Fx16::ZERO
                    } else {
                        hj
                    };
                    if masked.0 == 0 {
                        continue;
                    }
                    for k in 0..hdim {
                        acc[k].mac(masked, qwh[(g * hdim + j) * hdim + k]);
                    }
                }
                for k in 0..hdim {
                    hterm[g * hdim + k] = acc[k].finish(Fx16::ZERO);
                }
            }
            for k in 0..hdim {
                let r = sigmoid.eval(xterm[k].saturating_add(hterm[k]));
                let z = sigmoid.eval(
                    xterm[hdim + k].saturating_add(hterm[hdim + k]),
                );
                let n = tanh.eval(
                    xterm[2 * hdim + k].saturating_add(
                        r.saturating_mul(hterm[2 * hdim + k]),
                    ),
                );
                let one_minus_z = Fx16::ONE.saturating_add(Fx16(-z.0));
                h[k] = one_minus_z
                    .saturating_mul(n)
                    .saturating_add(z.saturating_mul(h[k]));
            }
            let got = engine.step(x);
            assert_eq!(
                got.iter().map(|v| v.0).collect::<Vec<_>>(),
                h.iter().map(|v| v.0).collect::<Vec<_>>(),
                "step {t}: parametric q16 GRU drifted from the \
                 legacy-op oracle"
            );
        }
    }

    /// Narrow formats still track the float GRU, with a coarser bound —
    /// the accuracy/resource trade `--precision` now opens for GRU
    /// designs.
    #[test]
    fn narrow_format_gru_tracks_float_loosely() {
        let mut rng = Rng::new(21);
        let (idim, hdim, t) = (2, 6, 10);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.3);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.3);
        let b = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let xs: Vec<f32> =
            (0..t * idim).map(|_| rng.normal() as f32 * 0.7).collect();
        let layer = GruLayer { wx: &wx, wh: &wh, b: &b };
        let zx = Tensor::ones(&[1, GRU_GATES, idim]);
        let zh = Tensor::ones(&[1, GRU_GATES, hdim]);
        let cache = gru::forward(&layer, &xs, 1, t, &zx, &zh);
        for (spec, tol) in [
            (QuantSpec::q16(), 0.06f32),
            (QuantSpec::q12(), 0.1),
            (QuantSpec::q8(), 0.3),
        ] {
            let mut e =
                GruEngine::with_format(&wx, &wh, &b, 1, 1, false, spec);
            let mut last = vec![];
            for ti in 0..t {
                let xq: Vec<Fx16> = xs[ti * idim..(ti + 1) * idim]
                    .iter()
                    .map(|&v| spec.act.quantize(v))
                    .collect();
                last = e.step(&xq).to_vec();
            }
            for k in 0..hdim {
                let got = spec.act.dequantize(last[k]);
                let want = cache.last_h()[k];
                assert!(
                    (got - want).abs() < tol,
                    "{} h[{k}]: fx {got} vs float {want}",
                    spec.name()
                );
            }
        }
    }

    /// Backend equivalence holds for the GRU engine too.
    #[test]
    fn all_kernel_backends_bit_identical_for_gru() {
        let mut rng = Rng::new(25);
        let (idim, hdim, steps) = (3, 6, 5);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.4);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.4);
        let b = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let zx: Vec<f32> = (0..GRU_GATES * idim)
            .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
            .collect();
        let zh: Vec<f32> = (0..GRU_GATES * hdim)
            .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
            .collect();
        let xs: Vec<Fx16> = (0..steps * idim)
            .map(|_| Fx16::from_f32(rng.normal() as f32))
            .collect();
        let mut outs = Vec::new();
        for backend in KernelBackend::ALL {
            let mut e = GruEngine::new(&wx, &wh, &b, 1, 1, true);
            e.set_backend(backend);
            e.set_masks(&zx, &zh);
            let mut h = vec![];
            for t in 0..steps {
                h = e.step(&xs[t * idim..(t + 1) * idim]).to_vec();
            }
            outs.push(h.iter().map(|v| v.0).collect::<Vec<_>>());
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn gru_state_bounded() {
        let mut rng = Rng::new(9);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, 1, 4], 1.0);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, 4, 4], 1.0);
        let b = rand_tensor(&mut rng, &[GRU_GATES, 4], 0.5);
        let mut e = GruEngine::new(&wx, &wh, &b, 1, 1, false);
        for i in 0..100 {
            let h = e.step(&[Fx16::from_f32((i as f32 * 0.7).sin() * 3.0)]);
            assert!(h.iter().all(|v| v.to_f32().abs() <= 1.01));
        }
    }

    #[test]
    fn gru_cheaper_than_lstm_in_dsps() {
        // 3 gates + 16-bit tail vs 4 gates + 32-bit tail: the GRU engine
        // must synthesise to fewer DSPs at the same (I, H, R).
        use crate::config::GATES;
        use crate::fpga::engine::LstmEngine;
        let mut rng = Rng::new(0);
        let (idim, hdim) = (8, 8);
        let gwx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.3);
        let gwh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.3);
        let gb = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let lwx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.3);
        let lwh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.3);
        let lb = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let g = GruEngine::new(&gwx, &gwh, &gb, 2, 2, true);
        let l = LstmEngine::new(&lwx, &lwh, &lb, 2, 2, true);
        assert!(g.dsps_synthesized() < l.dsps_synthesized());
        assert_eq!(g.ii(), l.ii());
        assert!(g.mask_bits() < l.mask_bits());
    }

    /// Packed q8 GRUs halve both their MVM DSPs and their weight-plane
    /// bytes — `--precision q8` is now a real axis for GRU designs.
    #[test]
    fn q8_gru_packs_dsps_and_weight_bytes() {
        let mut rng = Rng::new(2);
        let (idim, hdim) = (8, 8);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.2);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.2);
        let b = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let q16 = GruEngine::new(&wx, &wh, &b, 1, 1, true);
        let q8 = GruEngine::with_format(
            &wx, &wh, &b, 1, 1, true, QuantSpec::q8(),
        );
        assert!(q8.dsps_synthesized() < q16.dsps_synthesized());
        let bytes =
            |e: &GruEngine| -> usize {
                e.mvm_x
                    .iter()
                    .chain(e.mvm_h.iter())
                    .map(MvmUnit::weight_bytes)
                    .sum()
            };
        assert_eq!(bytes(&q8) * 2, bytes(&q16), "i8 planes halve bytes");
    }

    /// Streaming save/restore round-trips bitwise: a GRU resumed from
    /// a mid-sequence snapshot finishes the sequence identically to
    /// the uninterrupted engine.
    #[test]
    fn gru_state_snapshot_resumes_bitwise() {
        let mut rng = Rng::new(31);
        let (idim, hdim, steps, split) = (2, 6, 9, 4);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, idim, hdim], 0.4);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, hdim, hdim], 0.4);
        let b = rand_tensor(&mut rng, &[GRU_GATES, hdim], 0.1);
        let xs: Vec<Fx16> = (0..steps * idim)
            .map(|_| Fx16::from_f32(rng.normal() as f32))
            .collect();
        let mut whole = GruEngine::new(&wx, &wh, &b, 1, 1, false);
        let mut h_whole = vec![];
        for t in 0..steps {
            h_whole = whole.step(&xs[t * idim..(t + 1) * idim]).to_vec();
        }
        let mut first = GruEngine::new(&wx, &wh, &b, 1, 1, false);
        for t in 0..split {
            first.step(&xs[t * idim..(t + 1) * idim]);
        }
        let snap = first.state_words();
        assert_eq!(snap.len(), hdim.div_ceil(4));
        let mut second = GruEngine::new(&wx, &wh, &b, 1, 1, false);
        second.set_state_words(&snap);
        let mut h_resumed = vec![];
        for t in split..steps {
            h_resumed =
                second.step(&xs[t * idim..(t + 1) * idim]).to_vec();
        }
        assert_eq!(
            h_resumed.iter().map(|v| v.0).collect::<Vec<_>>(),
            h_whole.iter().map(|v| v.0).collect::<Vec<_>>()
        );
        assert_eq!(second.state_words().len(), snap.len());
    }

    #[test]
    fn masks_gate_input() {
        let mut rng = Rng::new(5);
        let wx = rand_tensor(&mut rng, &[GRU_GATES, 2, 4], 0.5);
        let wh = rand_tensor(&mut rng, &[GRU_GATES, 4, 4], 0.5);
        let b = Tensor::zeros(&[GRU_GATES, 4]);
        let mut e = GruEngine::new(&wx, &wh, &b, 1, 1, true);
        e.set_masks(&vec![0.0; GRU_GATES * 2], &vec![0.0; GRU_GATES * 4]);
        let h1 = e.step(&[Fx16::from_f32(1.0), Fx16::from_f32(-1.0)]).to_vec();
        let mut e2 = GruEngine::new(&wx, &wh, &b, 1, 1, true);
        let h2 = e2.step(&[Fx16::ZERO, Fx16::ZERO]).to_vec();
        assert_eq!(
            h1.iter().map(|v| v.0).collect::<Vec<_>>(),
            h2.iter().map(|v| v.0).collect::<Vec<_>>()
        );
    }
}
