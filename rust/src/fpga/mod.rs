//! Cycle-level simulator of the proposed streaming FPGA accelerator
//! (paper Sec. III, Figs. 2-6).
//!
//! Two coupled views of the same design:
//!
//! * **functional** ([`engine`], [`accel`]): parametric fixed-point
//!   numerics (8/12/16-bit activation paths, the paper's Q6.10 as the
//!   bit-exact default — `docs/quantization.md`) — quantised on-chip
//!   weights, DX mask gating, MVM engines with MAC accumulators,
//!   BRAM-LUT activations, widened cell path, LFSR Bernoulli samplers.
//!   This produces the *quantised model outputs* evaluated in
//!   Tables I/II.
//! * **timing** ([`pipeline`]): a cycle-accurate event simulation of the
//!   II-balanced layer pipeline with timestep pipelining (Fig. 5) and
//!   Bernoulli-sampling overlap (Fig. 4). This produces the "measured"
//!   latencies that validate the analytic model of Sec. IV-C (the paper
//!   reports ~2% model error; we reproduce that ablation).
//!
//! Resource accounting mirrors synthesis: each engine reports the DSPs it
//! actually allocates (ceil-per-unit, tiny multipliers folded into fabric
//! logic the way HLS does), which is compared against the analytic
//! resource model for the Table III "98% accuracy" claim.

pub mod accel;
pub mod engine;
pub mod gru;
pub mod pipeline;

pub use accel::{
    stream_req_seed, Accelerator, BatchRequest, McOutput, StreamError,
    StreamState,
};
pub use engine::{DenseEngine, LstmEngine, MvmUnit};
pub use pipeline::{PipelineReport, PipelineSim};
