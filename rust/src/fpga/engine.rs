//! Functional fixed-point engines: MVM units, the LSTM engine (4 gate
//! MVM pairs + LUT activations + widened cell tail) and the dense
//! engine — the hardware blocks of Fig. 2.
//!
//! All MVM inner loops run on the shared runtime-dispatched kernel
//! layer ([`crate::kernels`]): an engine can hold `rows` independent
//! sample lanes (MC samples x batched beats), each with its own DX
//! masks and architectural state, and every weight row fetched by a
//! timestep is MAC'd into all lanes — the paper's weight-fetch
//! amortisation. The classic single-lane API (`step`, `set_masks`,
//! `reset`) is the `rows == 1` special case and is bit-identical to the
//! pre-kernel implementation.
//!
//! Operand packing mirrors the accelerator's bandwidth story: weights
//! live in [`PackedWeights`] planes at their container width (`i8` rows
//! at q8), and the DX masks in [`BitPlanes`] bitsets (1 bit/element,
//! 16x smaller than the `Fx16` lanes they replaced) the kernels probe
//! directly. The kernel backend (`scalar | blocked | simd`,
//! `docs/kernels.md` §Backends) is captured from
//! [`crate::kernels::default_backend`] at construction and switchable
//! per engine via `set_backend` — every backend computes identical
//! bits.
//!
//! Engines are precision-parametric ([`crate::fixedpoint::QuantSpec`],
//! `docs/quantization.md`): the `new` constructors build the paper's
//! Q6.10/Q12.20 instance (bit-identical to the pre-refactor engines —
//! see the legacy-oracle test below), `with_format` opens the 8/12-bit
//! activation paths the DSE searches over.

use crate::config::GATES;
use crate::fixedpoint::{ActLut, Fx16, Fx32, MacAcc, QFormat, QuantSpec};
use crate::kernels::{self, BitPlanes, KernelBackend, MaskRef, PackedWeights};
use crate::tensor::Tensor;

/// One matrix-vector-multiply engine with a reuse factor: `in_dim` x
/// `out_dim` quantised weights; `reuse` time-multiplexes each physical
/// multiplier, so the unit has ceil(in*out/reuse) DSP multipliers and an
/// initiation interval of `reuse` cycles (divided by the format's DSP
/// packing — two ≤ 8-bit MACs share one slice).
pub struct MvmUnit {
    pub in_dim: usize,
    pub out_dim: usize,
    pub reuse: usize,
    /// Activation/weight format the unit is quantised in.
    pub fmt: QFormat,
    /// Row-major `[in_dim][out_dim]` quantised weights (on-chip),
    /// packed at the format's container width.
    pub weights: PackedWeights,
    /// Kernel backend this unit dispatches to (bit-identical across
    /// backends; cost shape differs).
    pub backend: KernelBackend,
}

impl MvmUnit {
    /// Quantise a float weight matrix `[in_dim][out_dim]` at Q6.10.
    pub fn new(weights: &[f32], in_dim: usize, out_dim: usize, reuse: usize) -> Self {
        Self::with_format(weights, in_dim, out_dim, reuse, QFormat::Q16_ACT)
    }

    /// Quantise a float weight matrix in an explicit format.
    pub fn with_format(
        weights: &[f32],
        in_dim: usize,
        out_dim: usize,
        reuse: usize,
        fmt: QFormat,
    ) -> Self {
        assert_eq!(weights.len(), in_dim * out_dim);
        assert!(reuse >= 1);
        let q: Vec<Fx16> =
            weights.iter().map(|&w| fmt.quantize(w)).collect();
        Self {
            in_dim,
            out_dim,
            reuse,
            fmt,
            weights: PackedWeights::pack(&q, in_dim, out_dim, fmt),
            backend: kernels::default_backend(),
        }
    }

    /// Switch the kernel backend (output bits unchanged).
    pub fn set_backend(&mut self, backend: KernelBackend) {
        self.backend = backend;
    }

    /// Weight-plane bytes the MVM streams (the packed-bandwidth axis
    /// the `kernels` bench reports).
    pub fn weight_bytes(&self) -> usize {
        self.weights.bytes()
    }

    /// y[k] += x . W[:,k] accumulated into wide MACs.
    pub fn mac_into(&self, x: &[Fx16], acc: &mut [MacAcc]) {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(acc.len(), self.out_dim);
        self.mac_rows(x, self.in_dim, acc, self.out_dim, 1);
    }

    /// Masked MAC: rows whose DX mask bit is zero are skipped entirely —
    /// fuses the DX gating into the MVM instead of materialising a masked
    /// copy of the input (EXPERIMENTS.md §Perf).
    pub fn mac_into_masked(
        &self,
        x: &[Fx16],
        mask: &[Fx16],
        acc: &mut [MacAcc],
    ) {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(mask.len(), self.in_dim);
        self.mac_rows_masked(
            x,
            self.in_dim,
            MaskRef::Lanes(mask, self.in_dim),
            acc,
            self.out_dim,
            1,
        );
    }

    /// Blocked multi-lane MAC through the kernel layer: one weight-row
    /// fetch serves all `rows` sample lanes, streamed from the packed
    /// plane.
    pub fn mac_rows(
        &self,
        x: &[Fx16],
        x_stride: usize,
        acc: &mut [MacAcc],
        acc_stride: usize,
        rows: usize,
    ) {
        self.backend.kernel().mvm_fx_packed(
            &self.weights,
            rows,
            x,
            x_stride,
            None,
            acc,
            acc_stride,
        );
    }

    /// Blocked multi-lane masked MAC: per-lane DX masks — strided
    /// `Fx16` lanes or bitplane views ([`MaskRef`]) — so the kernel
    /// reads gate lanes straight out of `[rows][GATES][dim]` mask
    /// buffers without gather copies.
    pub fn mac_rows_masked(
        &self,
        x: &[Fx16],
        x_stride: usize,
        mask: MaskRef,
        acc: &mut [MacAcc],
        acc_stride: usize,
        rows: usize,
    ) {
        self.backend.kernel().mvm_fx_packed(
            &self.weights,
            rows,
            x,
            x_stride,
            Some(mask),
            acc,
            acc_stride,
        );
    }

    /// Physical multipliers (DSP blocks) after time-multiplexing.
    pub fn multipliers(&self) -> u64 {
        div_ceil(self.in_dim * self.out_dim, self.reuse) as u64
    }

    /// DSPs as synthesis would allocate them: units that shrink below 4
    /// multipliers get folded into fabric logic by HLS (the paper adds 5%
    /// DSP slack for exactly this effect); at ≤ 8-bit operands two
    /// multipliers pack into one DSP48 slice.
    pub fn dsps_synthesized(&self) -> u64 {
        let m = self.multipliers();
        if m < 4 {
            0
        } else {
            m.div_ceil(self.fmt.macs_per_dsp())
        }
    }

    /// Initiation interval contribution: cycles to stream the full MVM
    /// through the multiplexed multipliers.
    pub fn ii(&self) -> u64 {
        self.reuse as u64
    }
}

fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// The full LSTM engine of Fig. 2: DX mask gating, 4 gate MVM pairs,
/// bias add, BRAM-LUT activations, widened cell tail.
pub struct LstmEngine {
    pub idim: usize,
    pub hdim: usize,
    /// Per gate: x-path MVM (reuse R_x).
    pub mvm_x: Vec<MvmUnit>,
    /// Per gate: h-path MVM (reuse R_h).
    pub mvm_h: Vec<MvmUnit>,
    /// Quantised biases `[4][H]`.
    pub bias: Vec<Fx16>,
    /// Whether this layer has MCD enabled (Bernoulli sampler + DX present).
    pub bayesian: bool,
    /// Activation + cell formats this engine is quantised in.
    pub spec: QuantSpec,
    sigmoid: ActLut,
    tanh: ActLut,
    /// Sample lanes currently configured (MC samples x batched beats).
    rows: usize,
    /// Current per-gate DX masks, `[rows][GATES * dim]` bitplanes
    /// (pre-sampled per input, Fig. 4) — 1 bit/element, consumed
    /// directly by the kernels.
    pub zx: BitPlanes,
    pub zh: BitPlanes,
    /// Architectural state registers, `[rows][hdim]`.
    h: Vec<Fx16>,
    c: Vec<Fx32>,
    // Scratch buffers (no allocation in the hot loop).
    acc: Vec<MacAcc>,
    pre: Vec<Fx16>,
}

impl LstmEngine {
    /// Build from float parameters in the crate ABI: wx `[4,I,H]`,
    /// wh `[4,H,H]`, b `[4,H]` — the paper's Q6.10/Q12.20 instance.
    pub fn new(
        wx: &Tensor,
        wh: &Tensor,
        b: &Tensor,
        rx: usize,
        rh: usize,
        bayesian: bool,
    ) -> Self {
        Self::with_format(wx, wh, b, rx, rh, bayesian, QuantSpec::q16())
    }

    /// Build at an explicit activation/cell format pair.
    #[allow(clippy::too_many_arguments)]
    pub fn with_format(
        wx: &Tensor,
        wh: &Tensor,
        b: &Tensor,
        rx: usize,
        rh: usize,
        bayesian: bool,
        spec: QuantSpec,
    ) -> Self {
        let idim = wx.shape[1];
        let hdim = wx.shape[2];
        let mvm_x = (0..GATES)
            .map(|g| {
                MvmUnit::with_format(
                    &wx.data[g * idim * hdim..(g + 1) * idim * hdim],
                    idim,
                    hdim,
                    rx,
                    spec.act,
                )
            })
            .collect();
        let mvm_h = (0..GATES)
            .map(|g| {
                MvmUnit::with_format(
                    &wh.data[g * hdim * hdim..(g + 1) * hdim * hdim],
                    hdim,
                    hdim,
                    rh,
                    spec.act,
                )
            })
            .collect();
        Self {
            idim,
            hdim,
            mvm_x,
            mvm_h,
            bias: b.data.iter().map(|&v| spec.act.quantize(v)).collect(),
            bayesian,
            spec,
            sigmoid: ActLut::sigmoid_fmt(spec.act),
            tanh: ActLut::tanh_fmt(spec.act),
            rows: 1,
            zx: BitPlanes::ones(1, GATES * idim),
            zh: BitPlanes::ones(1, GATES * hdim),
            h: vec![Fx16::ZERO; hdim],
            c: vec![Fx32::ZERO; hdim],
            acc: vec![MacAcc::new(); hdim],
            pre: vec![Fx16::ZERO; GATES * hdim],
        }
    }

    /// The format lane data enters/leaves this engine in.
    pub fn act_format(&self) -> QFormat {
        self.spec.act
    }

    /// Switch every gate MVM to a kernel backend (bits unchanged).
    pub fn set_backend(&mut self, backend: KernelBackend) {
        for u in self.mvm_x.iter_mut().chain(self.mvm_h.iter_mut()) {
            u.set_backend(backend);
        }
    }

    /// Sample lanes currently configured.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Configure `rows` independent sample lanes: state zeroed, masks
    /// all-ones (the non-Bayesian default — Bayesian layers get per-lane
    /// masks via [`LstmEngine::set_masks_row`]).
    pub fn set_rows(&mut self, rows: usize) {
        assert!(rows >= 1, "at least one sample lane");
        if rows != self.rows {
            self.rows = rows;
            self.zx = BitPlanes::ones(rows, GATES * self.idim);
            self.zh = BitPlanes::ones(rows, GATES * self.hdim);
            self.h = vec![Fx16::ZERO; rows * self.hdim];
            self.c = vec![Fx32::ZERO; rows * self.hdim];
            self.acc = vec![MacAcc::new(); rows * self.hdim];
            self.pre = vec![Fx16::ZERO; rows * GATES * self.hdim];
        } else {
            self.zx.fill_ones();
            self.zh.fill_ones();
            self.reset();
        }
    }

    /// Load pre-sampled masks into lane `r`. Masks are binary {0,1}.
    pub fn set_masks_row(&mut self, r: usize, zx: &[f32], zh: &[f32]) {
        debug_assert!(r < self.rows);
        debug_assert_eq!(zx.len(), GATES * self.idim);
        debug_assert_eq!(zh.len(), GATES * self.hdim);
        for (j, &s) in zx.iter().enumerate() {
            self.zx.set(r, j, s != 0.0);
        }
        for (j, &s) in zh.iter().enumerate() {
            self.zh.set(r, j, s != 0.0);
        }
    }

    /// Fill lane `r`'s masks straight from a Bernoulli bit source — the
    /// SIPO widening of Fig. 3, with no f32 intermediate. Draw order is
    /// the legacy contract: all `GATES * idim` zx bits, then all
    /// `GATES * hdim` zh bits, each in ascending element order, so a
    /// sampler driving this consumes exactly the stream positions the
    /// old `fill`-into-f32 + `set_masks_row` path did (oracle-tested
    /// below).
    pub fn fill_masks_row(
        &mut self,
        r: usize,
        mut keep: impl FnMut() -> bool,
    ) {
        debug_assert!(r < self.rows);
        self.zx.fill_row(r, &mut keep);
        self.zh.fill_row(r, &mut keep);
    }

    /// Word-level twin of [`LstmEngine::fill_masks_row`]: fill lane
    /// `r`'s masks 64 bits per call from a word source (`next(n)` =
    /// the next `n` stream bits, LSB-first — `BernoulliSampler::
    /// keep_word`). Same draw order and stream-position contract as
    /// the closure fill — all zx bits then all zh bits, exactly
    /// `mask_bits()` positions — so the two fills are interchangeable
    /// bit-for-bit (oracle-tested below).
    pub fn fill_masks_row_words(
        &mut self,
        r: usize,
        mut next: impl FnMut(u32) -> u64,
    ) {
        debug_assert!(r < self.rows);
        self.zx.fill_row_words(r, &mut next);
        self.zh.fill_row_words(r, &mut next);
    }

    /// Snapshot lane `r`'s packed mask words (zx row then zh row, tail
    /// padding included) — the unit the seed-indexed mask bank caches.
    pub fn mask_row_words(&self, r: usize) -> Vec<u64> {
        let mut words =
            Vec::with_capacity(self.zx.words_per_row() + self.zh.words_per_row());
        words.extend_from_slice(self.zx.row_words(r));
        words.extend_from_slice(self.zh.row_words(r));
        words
    }

    /// Restore lane `r`'s masks from a [`LstmEngine::mask_row_words`]
    /// snapshot — the mask-bank hit path. Byte-identical to having
    /// regenerated the row (the snapshot includes the tail padding).
    pub fn set_mask_row_words(&mut self, r: usize, words: &[u64]) {
        let zx_w = self.zx.words_per_row();
        assert_eq!(
            words.len(),
            zx_w + self.zh.words_per_row(),
            "cached row shape mismatch"
        );
        self.zx.copy_row_from_words(r, &words[..zx_w]);
        self.zh.copy_row_from_words(r, &words[zx_w..]);
    }

    /// Bytes of DX-mask state currently held (16x below the `Fx16`
    /// lane buffers these planes replaced).
    pub fn mask_bytes(&self) -> usize {
        self.zx.bytes() + self.zh.bytes()
    }

    /// Packed `u64` words one lane of architectural state occupies:
    /// `h` lanes at 4 x i16 per word, then `c` lanes at 2 x i32 per
    /// word — the unit the streaming session table keeps resident.
    pub fn state_words_per_row(&self) -> usize {
        self.hdim.div_ceil(4) + self.hdim.div_ceil(2)
    }

    /// Snapshot lane `r`'s architectural registers (h then c) into
    /// packed words — the streaming save path. Tail padding is zero,
    /// so save → restore round-trips bit-identically and snapshots of
    /// equal state compare equal bytewise.
    pub fn state_row_words(&self, r: usize) -> Vec<u64> {
        debug_assert!(r < self.rows);
        let hdim = self.hdim;
        let mut words = Vec::with_capacity(self.state_words_per_row());
        for chunk in self.h[r * hdim..(r + 1) * hdim].chunks(4) {
            let mut w = 0u64;
            for (i, v) in chunk.iter().enumerate() {
                w |= ((v.0 as u16) as u64) << (16 * i);
            }
            words.push(w);
        }
        for chunk in self.c[r * hdim..(r + 1) * hdim].chunks(2) {
            let mut w = 0u64;
            for (i, v) in chunk.iter().enumerate() {
                w |= ((v.0 as u32) as u64) << (32 * i);
            }
            words.push(w);
        }
        words
    }

    /// Restore lane `r`'s architectural registers from a
    /// [`LstmEngine::state_row_words`] snapshot — the streaming resume
    /// path. Bit-exact inverse of the save.
    pub fn set_state_row_words(&mut self, r: usize, words: &[u64]) {
        debug_assert!(r < self.rows);
        let hdim = self.hdim;
        let h_words = hdim.div_ceil(4);
        assert_eq!(
            words.len(),
            self.state_words_per_row(),
            "state row shape mismatch"
        );
        for k in 0..hdim {
            let w = words[k / 4];
            self.h[r * hdim + k] =
                Fx16(((w >> (16 * (k % 4))) & 0xFFFF) as u16 as i16);
        }
        for k in 0..hdim {
            let w = words[h_words + k / 2];
            self.c[r * hdim + k] =
                Fx32(((w >> (32 * (k % 2))) & 0xFFFF_FFFF) as u32 as i32);
        }
    }

    /// Load pre-sampled masks (one per input sequence) — the single-lane
    /// path.
    pub fn set_masks(&mut self, zx: &[f32], zh: &[f32]) {
        self.set_masks_row(0, zx, zh);
    }

    /// Reset h/c registers in every lane (new sequence).
    pub fn reset(&mut self) {
        self.h.fill(Fx16::ZERO);
        self.c.fill(Fx32::ZERO);
    }

    /// One timestep over all lanes: lane `r` consumes
    /// `xs[r * x_stride ..][..idim]`, updates its (h, c), and the
    /// returned slice exposes all lanes' h as `[rows][hdim]`. Each gate
    /// weight row is fetched once and MAC'd into every lane (the
    /// blocked-kernel amortisation); per-lane arithmetic is bit-identical
    /// to the single-lane [`LstmEngine::step`].
    pub fn step_rows(&mut self, xs: &[Fx16], x_stride: usize) -> &[Fx16] {
        let rows = self.rows;
        let hdim = self.hdim;
        let idim = self.idim;
        for g in 0..GATES {
            for a in self.acc.iter_mut() {
                *a = MacAcc::new();
            }
            // DX gating fused into the MVMs (no masked copy — §Perf);
            // gate-lane mask bits probed strided out of the
            // [rows][GATES * dim] bitplanes.
            self.mvm_x[g].mac_rows_masked(
                xs,
                x_stride,
                MaskRef::Bits(self.zx.lanes(g * idim)),
                &mut self.acc,
                hdim,
                rows,
            );
            self.mvm_h[g].mac_rows_masked(
                &self.h,
                hdim,
                MaskRef::Bits(self.zh.lanes(g * hdim)),
                &mut self.acc,
                hdim,
                rows,
            );
            for r in 0..rows {
                for k in 0..hdim {
                    self.pre[(r * GATES + g) * hdim + k] = self.acc
                        [r * hdim + k]
                        .finish_fmt(self.bias[g * hdim + k], self.spec.act);
                }
            }
        }
        // Tail: activations from BRAM LUTs, cell path widened
        // (Q12.20 at the paper's q16 instance).
        let spec = self.spec;
        for r in 0..rows {
            let pb = r * GATES * hdim;
            for k in 0..hdim {
                let i_g = self.sigmoid.eval(self.pre[pb + k]);
                let f_g = self.sigmoid.eval(self.pre[pb + hdim + k]);
                let g_g = self.tanh.eval(self.pre[pb + 2 * hdim + k]);
                let o_g = self.sigmoid.eval(self.pre[pb + 3 * hdim + k]);
                // c = f*c + i*g  (f*c on the 2-DSP 16x32 path).
                let fc = spec.cell_mul_act(self.c[r * hdim + k], f_g);
                let ig = spec.widen(spec.act.sat_mul(i_g, g_g));
                self.c[r * hdim + k] = spec.cell_add(fc, ig);
                let tanh_c =
                    self.tanh.eval(spec.narrow(self.c[r * hdim + k]));
                self.h[r * hdim + k] = spec.act.sat_mul(o_g, tanh_c);
            }
        }
        &self.h
    }

    /// One timestep: consume x_t, update (h, c), expose h_t — the
    /// single-lane path.
    pub fn step(&mut self, x: &[Fx16]) -> &[Fx16] {
        debug_assert_eq!(x.len(), self.idim);
        debug_assert_eq!(self.rows, 1, "use step_rows on a blocked engine");
        self.step_rows(x, self.idim)
    }

    /// All lanes' hidden state, `[rows][hdim]`.
    pub fn hidden(&self) -> &[Fx16] {
        &self.h
    }

    /// DSPs this engine synthesises to: gate MVMs + the 4H tail
    /// (f*c needs 2 DSPs per multiplier on the 32-bit path).
    pub fn dsps_synthesized(&self) -> u64 {
        let mvms: u64 = self
            .mvm_x
            .iter()
            .chain(self.mvm_h.iter())
            .map(MvmUnit::dsps_synthesized)
            .sum();
        mvms + 4 * self.hdim as u64
    }

    /// Engine initiation interval: the slowest gate path.
    pub fn ii(&self) -> u64 {
        self.mvm_x[0].ii().max(self.mvm_h[0].ii())
    }

    /// Mask bits the Bernoulli sampler must pre-generate per input.
    pub fn mask_bits(&self) -> usize {
        if self.bayesian {
            GATES * (self.idim + self.hdim)
        } else {
            0
        }
    }
}

/// The final dense layer: one MVM unit with reuse R_d.
pub struct DenseEngine {
    pub mvm: MvmUnit,
    pub bias: Vec<Fx16>,
    /// Activation/weight format (no cell path in the dense head).
    pub fmt: QFormat,
    rows: usize,
    acc: Vec<MacAcc>,
    out: Vec<Fx16>,
}

impl DenseEngine {
    pub fn new(w: &Tensor, b: &Tensor, rd: usize) -> Self {
        Self::with_format(w, b, rd, QFormat::Q16_ACT)
    }

    pub fn with_format(
        w: &Tensor,
        b: &Tensor,
        rd: usize,
        fmt: QFormat,
    ) -> Self {
        let (f, o) = (w.shape[0], w.shape[1]);
        Self {
            mvm: MvmUnit::with_format(&w.data, f, o, rd, fmt),
            bias: b.data.iter().map(|&v| fmt.quantize(v)).collect(),
            fmt,
            rows: 1,
            acc: vec![MacAcc::new(); o],
            out: vec![Fx16::ZERO; o],
        }
    }

    /// Configure `rows` sample lanes.
    pub fn set_rows(&mut self, rows: usize) {
        assert!(rows >= 1, "at least one sample lane");
        if rows != self.rows {
            let o = self.mvm.out_dim;
            self.rows = rows;
            self.acc = vec![MacAcc::new(); rows * o];
            self.out = vec![Fx16::ZERO; rows * o];
        }
    }

    /// Switch the head MVM to a kernel backend (bits unchanged).
    pub fn set_backend(&mut self, backend: KernelBackend) {
        self.mvm.set_backend(backend);
    }

    /// One dense pass over all lanes; returns `[rows][out_dim]`.
    pub fn step_rows(&mut self, xs: &[Fx16], x_stride: usize) -> &[Fx16] {
        let o = self.mvm.out_dim;
        for a in self.acc.iter_mut() {
            *a = MacAcc::new();
        }
        self.mvm.mac_rows(xs, x_stride, &mut self.acc, o, self.rows);
        for r in 0..self.rows {
            for k in 0..o {
                self.out[r * o + k] =
                    self.acc[r * o + k].finish_fmt(self.bias[k], self.fmt);
            }
        }
        &self.out
    }

    pub fn step(&mut self, x: &[Fx16]) -> &[Fx16] {
        debug_assert_eq!(self.rows, 1, "use step_rows on a blocked engine");
        self.step_rows(x, self.mvm.in_dim)
    }

    pub fn dsps_synthesized(&self) -> u64 {
        self.mvm.dsps_synthesized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: &[usize], s: f64) -> Tensor {
        Tensor::from_fn(shape, |_| rng.normal_scaled(0.0, s) as f32)
    }

    #[test]
    fn mvm_matches_float() {
        let mut rng = Rng::new(1);
        let (i, o) = (6, 5);
        let w = rand_tensor(&mut rng, &[i, o], 0.4);
        let unit = MvmUnit::new(&w.data, i, o, 3);
        let x: Vec<f32> = (0..i).map(|_| rng.normal() as f32).collect();
        let xq: Vec<Fx16> = x.iter().map(|&v| Fx16::from_f32(v)).collect();
        let mut acc = vec![MacAcc::new(); o];
        unit.mac_into(&xq, &mut acc);
        for k in 0..o {
            let got = acc[k].finish(Fx16::ZERO).to_f32();
            let want: f32 = (0..i).map(|r| x[r] * w.at2(r, k)).sum();
            assert!((got - want).abs() < 0.02, "col {k}: {got} vs {want}");
        }
    }

    #[test]
    fn mvm_resource_accounting() {
        let w = Tensor::zeros(&[8, 8]);
        let u = MvmUnit::new(&w.data, 8, 8, 5);
        assert_eq!(u.multipliers(), 13); // ceil(64/5)
        assert_eq!(u.ii(), 5);
        // Tiny units fold into fabric.
        let small = MvmUnit::new(&Tensor::zeros(&[1, 8]).data, 1, 8, 4);
        assert_eq!(small.multipliers(), 2);
        assert_eq!(small.dsps_synthesized(), 0);
    }

    #[test]
    fn engine_matches_float_reference_cell() {
        // One step of the fixed-point engine vs the float nn cell.
        let mut rng = Rng::new(3);
        let (idim, hdim) = (3, 6);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.3);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.3);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let mut engine = LstmEngine::new(&wx, &wh, &b, 1, 1, false);
        let x: Vec<f32> = (0..idim).map(|_| rng.normal() as f32).collect();
        let xq: Vec<Fx16> = x.iter().map(|&v| Fx16::from_f32(v)).collect();
        let h_fx = engine.step(&xq).to_vec();

        // Float reference via nn::lstm with ones masks, t=1.
        use crate::nn::lstm::{forward, LstmLayer};
        let layer = LstmLayer { wx: &wx, wh: &wh, b: &b };
        let zx = Tensor::ones(&[1, GATES, idim]);
        let zh = Tensor::ones(&[1, GATES, hdim]);
        let cache = forward(&layer, &x, 1, 1, &zx, &zh);
        for k in 0..hdim {
            let got = h_fx[k].to_f32();
            let want = cache.last_h()[k];
            assert!(
                (got - want).abs() < 0.03,
                "h[{k}]: fx {got} vs float {want}"
            );
        }
    }

    #[test]
    fn dx_masks_gate_features() {
        let mut rng = Rng::new(5);
        let (idim, hdim) = (2, 4);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.5);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.5);
        let b = Tensor::zeros(&[GATES, hdim]);
        let mut e = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        // Mask everything -> step(x) behaves like x = 0.
        let zx = vec![0.0; GATES * idim];
        let zh = vec![0.0; GATES * hdim];
        e.set_masks(&zx, &zh);
        let x = vec![Fx16::from_f32(1.0); idim];
        let h1 = e.step(&x).to_vec();
        let mut e2 = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        let h2 = e2.step(&vec![Fx16::ZERO; idim]).to_vec();
        assert_eq!(
            h1.iter().map(|v| v.0).collect::<Vec<_>>(),
            h2.iter().map(|v| v.0).collect::<Vec<_>>()
        );
    }

    #[test]
    fn engine_state_resets() {
        let mut rng = Rng::new(7);
        let wx = rand_tensor(&mut rng, &[GATES, 1, 4], 0.5);
        let wh = rand_tensor(&mut rng, &[GATES, 4, 4], 0.5);
        let b = rand_tensor(&mut rng, &[GATES, 4], 0.2);
        let mut e = LstmEngine::new(&wx, &wh, &b, 1, 1, false);
        let x = [Fx16::from_f32(0.7)];
        let h_first = e.step(&x).to_vec();
        e.step(&x);
        e.reset();
        let h_again = e.step(&x).to_vec();
        assert_eq!(
            h_first.iter().map(|v| v.0).collect::<Vec<_>>(),
            h_again.iter().map(|v| v.0).collect::<Vec<_>>()
        );
    }

    #[test]
    fn engine_dsps_include_tail() {
        let wx = Tensor::zeros(&[GATES, 8, 8]);
        let wh = Tensor::zeros(&[GATES, 8, 8]);
        let b = Tensor::zeros(&[GATES, 8]);
        let e = LstmEngine::new(&wx, &wh, &b, 1, 1, false);
        // 4 gates * 64 multipliers on each path + 4*8 tail.
        assert_eq!(e.dsps_synthesized(), 4 * 64 + 4 * 64 + 32);
        assert_eq!(e.ii(), 1);
        assert_eq!(e.mask_bits(), 0);
        let eb = LstmEngine::new(&wx, &wh, &b, 4, 4, true);
        assert_eq!(eb.mask_bits(), GATES * 16);
        assert_eq!(eb.ii(), 4);
    }

    /// Sample lanes are bit-identical to independent single-lane
    /// engines over a multi-step sequence — the engine-level half of
    /// the blocked-kernel contract.
    #[test]
    fn blocked_lanes_match_single_lane_engines_bitwise() {
        let mut rng = Rng::new(11);
        let (idim, hdim, rows, steps) = (3, 5, 4, 6);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.4);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.4);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        // Per-lane random masks and inputs.
        let masks: Vec<(Vec<f32>, Vec<f32>)> = (0..rows)
            .map(|_| {
                let zx: Vec<f32> = (0..GATES * idim)
                    .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                    .collect();
                let zh: Vec<f32> = (0..GATES * hdim)
                    .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                    .collect();
                (zx, zh)
            })
            .collect();
        let xs: Vec<Fx16> = (0..steps * rows * idim)
            .map(|_| Fx16::from_f32(rng.normal() as f32))
            .collect();

        let mut blocked = LstmEngine::new(&wx, &wh, &b, 2, 1, true);
        blocked.set_rows(rows);
        for (r, (zx, zh)) in masks.iter().enumerate() {
            blocked.set_masks_row(r, zx, zh);
        }
        let mut h_blocked = Vec::new();
        for t in 0..steps {
            let frame = &xs[t * rows * idim..(t + 1) * rows * idim];
            h_blocked = blocked.step_rows(frame, idim).to_vec();
        }

        for (r, (zx, zh)) in masks.iter().enumerate() {
            let mut single = LstmEngine::new(&wx, &wh, &b, 2, 1, true);
            single.set_masks(zx, zh);
            let mut h_single = Vec::new();
            for t in 0..steps {
                let x =
                    &xs[(t * rows + r) * idim..(t * rows + r + 1) * idim];
                h_single = single.step(x).to_vec();
            }
            assert_eq!(
                h_blocked[r * hdim..(r + 1) * hdim]
                    .iter()
                    .map(|v| v.0)
                    .collect::<Vec<_>>(),
                h_single.iter().map(|v| v.0).collect::<Vec<_>>(),
                "lane {r} must match its single-lane engine bit-for-bit"
            );
        }
    }

    #[test]
    fn dense_engine_blocked_rows_match_single() {
        let mut rng = Rng::new(13);
        let w = rand_tensor(&mut rng, &[6, 4], 0.5);
        let b = rand_tensor(&mut rng, &[4], 0.2);
        let rows = 3;
        let xs: Vec<Fx16> = (0..rows * 6)
            .map(|_| Fx16::from_f32(rng.normal() as f32))
            .collect();
        let mut blocked = DenseEngine::new(&w, &b, 2);
        blocked.set_rows(rows);
        let y = blocked.step_rows(&xs, 6).to_vec();
        for r in 0..rows {
            let mut single = DenseEngine::new(&w, &b, 2);
            let yr = single.step(&xs[r * 6..(r + 1) * 6]).to_vec();
            assert_eq!(
                y[r * 4..(r + 1) * 4]
                    .iter()
                    .map(|v| v.0)
                    .collect::<Vec<_>>(),
                yr.iter().map(|v| v.0).collect::<Vec<_>>()
            );
        }
    }

    /// Engine-level half of the Q6.10 bit-exactness contract: the
    /// parametric engine at `QuantSpec::q16()` must reproduce, bit for
    /// bit, a from-scratch reference step written entirely in the frozen
    /// legacy `Fx16`/`Fx32`/`MacAcc::finish` ops (the pre-refactor
    /// implementation).
    #[test]
    fn q16_engine_matches_legacy_op_oracle_bitwise() {
        let mut rng = Rng::new(29);
        let (idim, hdim, steps) = (3, 5, 8);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.4);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.4);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let zx: Vec<f32> = (0..GATES * idim)
            .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
            .collect();
        let zh: Vec<f32> = (0..GATES * hdim)
            .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
            .collect();
        let xs: Vec<Fx16> = (0..steps * idim)
            .map(|_| Fx16::from_f32(rng.normal() as f32))
            .collect();

        // Parametric engine at the q16 spec.
        let mut engine =
            LstmEngine::with_format(&wx, &wh, &b, 1, 1, true, QuantSpec::q16());
        engine.set_masks(&zx, &zh);

        // Legacy oracle: quantise with Fx16::from_f32, MAC in ascending
        // weight-row order, finish with MacAcc::finish, tail with the
        // frozen mul_fx16 / widen / narrow / saturating_mul ops and the
        // legacy Q6.10 LUTs.
        let sigmoid = ActLut::sigmoid();
        let tanh = ActLut::tanh();
        let qw = |t: &Tensor| -> Vec<Fx16> {
            t.data.iter().map(|&v| Fx16::from_f32(v)).collect()
        };
        let (qwx, qwh, qb) = (qw(&wx), qw(&wh), qw(&b));
        let mut h = vec![Fx16::ZERO; hdim];
        let mut c = vec![Fx32::ZERO; hdim];
        for t in 0..steps {
            let x = &xs[t * idim..(t + 1) * idim];
            let mut pre = vec![Fx16::ZERO; GATES * hdim];
            for g in 0..GATES {
                let mut acc = vec![MacAcc::new(); hdim];
                for (i, &xi) in x.iter().enumerate() {
                    if xi.0 == 0 || zx[g * idim + i] == 0.0 {
                        continue;
                    }
                    for k in 0..hdim {
                        acc[k].mac(xi, qwx[(g * idim + i) * hdim + k]);
                    }
                }
                for (j, &hj) in h.iter().enumerate() {
                    if hj.0 == 0 || zh[g * hdim + j] == 0.0 {
                        continue;
                    }
                    for k in 0..hdim {
                        acc[k].mac(hj, qwh[(g * hdim + j) * hdim + k]);
                    }
                }
                for k in 0..hdim {
                    pre[g * hdim + k] =
                        acc[k].finish(qb[g * hdim + k]);
                }
            }
            for k in 0..hdim {
                let i_g = sigmoid.eval(pre[k]);
                let f_g = sigmoid.eval(pre[hdim + k]);
                let g_g = tanh.eval(pre[2 * hdim + k]);
                let o_g = sigmoid.eval(pre[3 * hdim + k]);
                let fc = c[k].mul_fx16(f_g);
                let ig = i_g.saturating_mul(g_g).widen();
                c[k] = fc.saturating_add(ig);
                let tanh_c = tanh.eval(c[k].narrow());
                h[k] = o_g.saturating_mul(tanh_c);
            }
            let got = engine.step(x);
            assert_eq!(
                got.iter().map(|v| v.0).collect::<Vec<_>>(),
                h.iter().map(|v| v.0).collect::<Vec<_>>(),
                "step {t}: parametric q16 engine drifted from the \
                 legacy-op oracle"
            );
        }
    }

    /// Narrow formats still track the float cell, just with a coarser
    /// error bound — the accuracy/resource trade the DSE measures.
    #[test]
    fn narrow_format_engines_track_float_loosely() {
        let mut rng = Rng::new(17);
        let (idim, hdim) = (3, 6);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.3);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.3);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let x: Vec<f32> =
            (0..idim).map(|_| rng.normal() as f32 * 0.8).collect();

        use crate::nn::lstm::{forward, LstmLayer};
        let layer = LstmLayer { wx: &wx, wh: &wh, b: &b };
        let zx = Tensor::ones(&[1, GATES, idim]);
        let zh = Tensor::ones(&[1, GATES, hdim]);
        let cache = forward(&layer, &x, 1, 1, &zx, &zh);

        for (spec, tol) in [
            (QuantSpec::q16(), 0.03f32),
            (QuantSpec::q12(), 0.05),
            (QuantSpec::q8(), 0.2),
        ] {
            let mut e =
                LstmEngine::with_format(&wx, &wh, &b, 1, 1, false, spec);
            let xq: Vec<Fx16> =
                x.iter().map(|&v| spec.act.quantize(v)).collect();
            let h = e.step(&xq).to_vec();
            for k in 0..hdim {
                let got = spec.act.dequantize(h[k]);
                let want = cache.last_h()[k];
                assert!(
                    (got - want).abs() < tol,
                    "{} h[{k}]: fx {got} vs float {want}",
                    spec.name()
                );
            }
        }
    }

    /// Blocked sample lanes stay bit-identical to single-lane engines at
    /// a narrow format too (the kernel contract is format-agnostic).
    #[test]
    fn q8_blocked_lanes_match_single_lane_bitwise() {
        let mut rng = Rng::new(23);
        let (idim, hdim, rows, steps) = (2, 4, 3, 5);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.4);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.4);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let spec = QuantSpec::q8();
        let xs: Vec<Fx16> = (0..steps * rows * idim)
            .map(|_| spec.act.quantize(rng.normal() as f32))
            .collect();
        let mut blocked =
            LstmEngine::with_format(&wx, &wh, &b, 1, 1, false, spec);
        blocked.set_rows(rows);
        let mut h_blocked = Vec::new();
        for t in 0..steps {
            let frame = &xs[t * rows * idim..(t + 1) * rows * idim];
            h_blocked = blocked.step_rows(frame, idim).to_vec();
        }
        for r in 0..rows {
            let mut single =
                LstmEngine::with_format(&wx, &wh, &b, 1, 1, false, spec);
            let mut h_single = Vec::new();
            for t in 0..steps {
                let x = &xs[(t * rows + r) * idim..(t * rows + r + 1) * idim];
                h_single = single.step(x).to_vec();
            }
            assert_eq!(
                h_blocked[r * hdim..(r + 1) * hdim]
                    .iter()
                    .map(|v| v.0)
                    .collect::<Vec<_>>(),
                h_single.iter().map(|v| v.0).collect::<Vec<_>>(),
                "q8 lane {r}"
            );
        }
    }

    #[test]
    fn q8_mvm_packs_two_macs_per_dsp() {
        let w = Tensor::zeros(&[8, 8]);
        let q16 = MvmUnit::with_format(&w.data, 8, 8, 1, QFormat::Q16_ACT);
        let q8 = MvmUnit::with_format(&w.data, 8, 8, 1, QFormat::Q8_ACT);
        assert_eq!(q16.dsps_synthesized(), 64);
        assert_eq!(q8.dsps_synthesized(), 32, "INT8 packing halves DSPs");
        // Folding below 4 multipliers still applies.
        let tiny = MvmUnit::with_format(
            &Tensor::zeros(&[1, 3]).data,
            1,
            3,
            1,
            QFormat::Q8_ACT,
        );
        assert_eq!(tiny.dsps_synthesized(), 0);
    }

    /// Engine-level leg of the backend-equivalence contract: scalar,
    /// blocked and simd backends produce bit-identical hidden state
    /// over a masked multi-lane, multi-step run.
    #[test]
    fn all_kernel_backends_bit_identical_at_engine_level() {
        use crate::kernels::KernelBackend;
        let mut rng = Rng::new(37);
        let (idim, hdim, rows, steps) = (3, 5, 4, 6);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.4);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.4);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let masks: Vec<(Vec<f32>, Vec<f32>)> = (0..rows)
            .map(|_| {
                let zx: Vec<f32> = (0..GATES * idim)
                    .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                    .collect();
                let zh: Vec<f32> = (0..GATES * hdim)
                    .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                    .collect();
                (zx, zh)
            })
            .collect();
        let xs: Vec<Fx16> = (0..steps * rows * idim)
            .map(|_| Fx16::from_f32(rng.normal() as f32))
            .collect();
        for spec in [QuantSpec::q16(), QuantSpec::q8()] {
            let mut outs = Vec::new();
            for backend in KernelBackend::ALL {
                let mut e = LstmEngine::with_format(
                    &wx, &wh, &b, 2, 1, true, spec,
                );
                e.set_backend(backend);
                e.set_rows(rows);
                for (r, (zx, zh)) in masks.iter().enumerate() {
                    e.set_masks_row(r, zx, zh);
                }
                let mut h = Vec::new();
                for t in 0..steps {
                    let frame =
                        &xs[t * rows * idim..(t + 1) * rows * idim];
                    h = e.step_rows(frame, idim).to_vec();
                }
                outs.push((
                    backend.name(),
                    h.iter().map(|v| v.0).collect::<Vec<_>>(),
                ));
            }
            for w in outs.windows(2) {
                assert_eq!(
                    w[0].1, w[1].1,
                    "{}: {} != {} at engine level",
                    spec.name(),
                    w[0].0,
                    w[1].0
                );
            }
        }
    }

    /// Bitplane mask oracle: filling lane masks straight from the
    /// sampler's bit stream consumes exactly the draws — and lands
    /// exactly the bits — of the legacy f32-buffer fill +
    /// `set_masks_row` path.
    #[test]
    fn fill_masks_row_matches_legacy_f32_fill_bit_for_bit() {
        use crate::lfsr::BernoulliSampler;
        let mut rng = Rng::new(43);
        let (idim, hdim, rows) = (5, 7, 3);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.3);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.3);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let mut legacy = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        let mut planes = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        legacy.set_rows(rows);
        planes.set_rows(rows);
        let mut s1 = BernoulliSampler::new(77);
        let mut s2 = BernoulliSampler::new(77);
        for r in 0..rows {
            // Legacy order: fill zx f32 buffer, fill zh, convert.
            let mut zx = vec![0.0f32; GATES * idim];
            let mut zh = vec![0.0f32; GATES * hdim];
            s1.fill(&mut zx);
            s1.fill(&mut zh);
            legacy.set_masks_row(r, &zx, &zh);
            // New order: bits straight off the same stream.
            planes.fill_masks_row(r, || s2.sample() != 0.0);
        }
        assert_eq!(s1.cycles(), s2.cycles(), "same draw count");
        for r in 0..rows {
            for j in 0..GATES * idim {
                assert_eq!(legacy.zx.get(r, j), planes.zx.get(r, j));
            }
            for j in 0..GATES * hdim {
                assert_eq!(legacy.zh.get(r, j), planes.zh.get(r, j));
            }
        }
        // And the planes undercut the Fx16 lanes they replaced even at
        // these toy dims (the full 16x shows at word-filling widths —
        // `kernels::bitplane` pins that ratio exactly).
        let fx16_bytes = rows * GATES * (idim + hdim) * 2;
        assert!(
            planes.mask_bytes() < fx16_bytes,
            "mask planes {}B vs {}B of Fx16 lanes",
            planes.mask_bytes(),
            fx16_bytes
        );
    }

    /// Word-level mask fill oracle: `fill_masks_row_words` driven by
    /// `keep_word` lands exactly the bits — and consumes exactly the
    /// stream positions — of the closure fill driven by `sample()`,
    /// and a row snapshot restores byte-identically (the mask-bank
    /// contract end to end at the engine level).
    #[test]
    fn fill_masks_row_words_matches_closure_fill_bit_for_bit() {
        use crate::lfsr::BernoulliSampler;
        let mut rng = Rng::new(47);
        let (idim, hdim, rows) = (5, 7, 3);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.3);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.3);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let mut by_bit = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        let mut by_word = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        by_bit.set_rows(rows);
        by_word.set_rows(rows);
        let mut s1 = BernoulliSampler::new(91);
        let mut s2 = BernoulliSampler::new(91);
        for r in 0..rows {
            by_bit.fill_masks_row(r, || s1.sample() != 0.0);
            by_word.fill_masks_row_words(r, |n| s2.keep_word(n));
        }
        assert_eq!(s1.cycles(), s2.cycles(), "same stream positions");
        for r in 0..rows {
            for j in 0..GATES * idim {
                assert_eq!(by_bit.zx.get(r, j), by_word.zx.get(r, j));
            }
            for j in 0..GATES * hdim {
                assert_eq!(by_bit.zh.get(r, j), by_word.zh.get(r, j));
            }
        }
        // Row snapshot -> restore is byte-identical (bank hit path).
        let snap = by_word.mask_row_words(1);
        let mut restored = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        restored.set_rows(rows);
        restored.set_mask_row_words(2, &snap);
        for j in 0..GATES * idim {
            assert_eq!(restored.zx.get(2, j), by_word.zx.get(1, j));
        }
        for j in 0..GATES * hdim {
            assert_eq!(restored.zh.get(2, j), by_word.zh.get(1, j));
        }
        assert_eq!(restored.mask_row_words(2), snap);
    }

    /// Engine-level streaming contract: snapshotting a lane's (h, c)
    /// mid-sequence and restoring it into a fresh engine continues the
    /// sequence bit-identically to the uninterrupted engine — for any
    /// split point, including across lanes.
    #[test]
    fn state_snapshot_resumes_sequences_bitwise() {
        let mut rng = Rng::new(53);
        let (idim, hdim, rows, steps) = (3, 5, 3, 8);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.4);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.4);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let masks: Vec<(Vec<f32>, Vec<f32>)> = (0..rows)
            .map(|_| {
                let zx: Vec<f32> = (0..GATES * idim)
                    .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                    .collect();
                let zh: Vec<f32> = (0..GATES * hdim)
                    .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                    .collect();
                (zx, zh)
            })
            .collect();
        let xs: Vec<Fx16> = (0..steps * rows * idim)
            .map(|_| Fx16::from_f32(rng.normal() as f32))
            .collect();
        let set_masks = |e: &mut LstmEngine| {
            e.set_rows(rows);
            for (r, (zx, zh)) in masks.iter().enumerate() {
                e.set_masks_row(r, zx, zh);
            }
        };
        // Reference: one uninterrupted pass.
        let mut whole = LstmEngine::new(&wx, &wh, &b, 2, 1, true);
        set_masks(&mut whole);
        let mut h_whole = Vec::new();
        for t in 0..steps {
            h_whole = whole
                .step_rows(&xs[t * rows * idim..(t + 1) * rows * idim], idim)
                .to_vec();
        }
        for split in [1, 3, steps - 1] {
            let mut first = LstmEngine::new(&wx, &wh, &b, 2, 1, true);
            set_masks(&mut first);
            for t in 0..split {
                first.step_rows(
                    &xs[t * rows * idim..(t + 1) * rows * idim],
                    idim,
                );
            }
            let snaps: Vec<Vec<u64>> =
                (0..rows).map(|r| first.state_row_words(r)).collect();
            for s in &snaps {
                assert_eq!(s.len(), first.state_words_per_row());
            }
            // Resume in a *fresh* engine (state crossed a boundary).
            let mut second = LstmEngine::new(&wx, &wh, &b, 2, 1, true);
            set_masks(&mut second);
            for (r, s) in snaps.iter().enumerate() {
                second.set_state_row_words(r, s);
            }
            let mut h_resumed = Vec::new();
            for t in split..steps {
                h_resumed = second
                    .step_rows(
                        &xs[t * rows * idim..(t + 1) * rows * idim],
                        idim,
                    )
                    .to_vec();
            }
            assert_eq!(
                h_resumed.iter().map(|v| v.0).collect::<Vec<_>>(),
                h_whole.iter().map(|v| v.0).collect::<Vec<_>>(),
                "resume at split {split} must be bitwise"
            );
            // Round trip: save → restore → save is byte-stable.
            for r in 0..rows {
                let again = second.state_row_words(r);
                second.set_state_row_words(r, &again);
                assert_eq!(second.state_row_words(r), again);
            }
        }
    }

    #[test]
    #[should_panic(expected = "state row shape mismatch")]
    fn set_state_row_words_rejects_wrong_shape() {
        let wx = Tensor::zeros(&[GATES, 3, 4]);
        let wh = Tensor::zeros(&[GATES, 4, 4]);
        let b = Tensor::zeros(&[GATES, 4]);
        let mut e = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        e.set_state_row_words(0, &[0u64; 1]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn set_mask_row_words_rejects_wrong_shape() {
        let wx = Tensor::zeros(&[GATES, 3, 4]);
        let wh = Tensor::zeros(&[GATES, 4, 4]);
        let b = Tensor::zeros(&[GATES, 4]);
        let mut e = LstmEngine::new(&wx, &wh, &b, 1, 1, true);
        e.set_mask_row_words(0, &[0u64; 7]);
    }

    #[test]
    fn packed_weight_planes_shrink_with_the_format() {
        let w = Tensor::zeros(&[8, 8]);
        let q16 = MvmUnit::with_format(&w.data, 8, 8, 1, QFormat::Q16_ACT);
        let q8 = MvmUnit::with_format(&w.data, 8, 8, 1, QFormat::Q8_ACT);
        assert_eq!(q16.weight_bytes(), 128, "i16 rows at q16");
        assert_eq!(q8.weight_bytes(), 64, "i8 rows halve weight traffic");
    }

    #[test]
    fn dense_engine_matches_float() {
        let mut rng = Rng::new(9);
        let w = rand_tensor(&mut rng, &[5, 3], 0.5);
        let b = rand_tensor(&mut rng, &[3], 0.2);
        let mut d = DenseEngine::new(&w, &b, 2);
        let x: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
        let xq: Vec<Fx16> = x.iter().map(|&v| Fx16::from_f32(v)).collect();
        let y = d.step(&xq);
        for k in 0..3 {
            let want: f32 =
                (0..5).map(|i| x[i] * w.at2(i, k)).sum::<f32>() + b.data[k];
            assert!((y[k].to_f32() - want).abs() < 0.02);
        }
    }
}
