# Synthetic ECG5000-equivalent generator checks (DESIGN.md §Substitutions).

import numpy as np

from compile import ecg


def test_shapes_and_dtypes():
    x, y = ecg.generate(64, seed=1)
    assert x.shape == (64, ecg.T, 1) and x.dtype == np.float32
    assert y.shape == (64,) and y.dtype == np.int32
    assert set(np.unique(y)) <= {0, 1, 2, 3}


def test_deterministic():
    x1, y1 = ecg.generate(32, seed=9)
    x2, y2 = ecg.generate(32, seed=9)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_z_normalised_per_sample():
    x, _ = ecg.generate(16, seed=2)
    means = x[:, :, 0].mean(axis=1)
    stds = x[:, :, 0].std(axis=1)
    np.testing.assert_allclose(means, 0.0, atol=1e-5)
    np.testing.assert_allclose(stds, 1.0, atol=1e-4)


def test_class_imbalance_matches_ecg5000():
    _, y = ecg.generate(5000, seed=0)
    frac_normal = (y == 0).mean()
    assert 0.52 < frac_normal < 0.65   # ECG5000 is ~58% normal


def test_splits():
    (xtr, ytr), (xte, yte) = ecg.splits(seed=0)
    assert xtr.shape[0] == 500 and xte.shape[0] == 4500


def test_anomalies_differ_from_normal():
    """Mean anomalous beat must be far from mean normal beat (the signal
    the autoencoder exploits)."""
    x, y = ecg.generate(2000, seed=3)
    mean_normal = x[y == 0, :, 0].mean(axis=0)
    for c in (1, 2, 3):
        mean_c = x[y == c, :, 0].mean(axis=0)
        rmse = np.sqrt(((mean_c - mean_normal) ** 2).mean())
        assert rmse > 0.3, (c, rmse)
