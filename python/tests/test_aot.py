# AOT lowering round-trip: a small architecture lowers to HLO text that
# the XLA text parser accepts, with the positional ABI the manifest
# promises (the Rust-side contract is re-checked in rust/tests/).

import jax

from compile.aot import build_forward, build_train
from compile.model import ArchConfig


def small_cfg():
    return ArchConfig("classify", 4, 1, "Y", seq_len=10)


def test_forward_lowering_abi():
    cfg = small_cfg()
    text, args, outs = build_forward(cfg, n=3)
    # HLO text sanity: an ENTRY computation over f32 params.
    assert "ENTRY" in text and "f32" in text
    # ABI: params (3*L+2), xs, masks (2*L).
    nl = cfg.num_lstm_layers
    assert len(args) == (3 * nl + 2) + 1 + 2 * nl
    assert args[0]["name"] == "lstm0.wx"
    assert args[3 * nl + 2]["name"] == "xs"
    assert args[3 * nl + 2]["shape"] == [3, 10, 1]
    assert outs[0]["name"] == "probs"
    assert outs[0]["shape"] == [3, 4]
    # The entry computation takes exactly len(args) parameters: the last
    # index exists, one past it does not. (Counting "parameter(" naively
    # overcounts — nested scan computations have their own parameters.)
    assert f"parameter({len(args) - 1})" in text
    assert f"parameter({len(args)})" not in text


def test_train_lowering_abi():
    cfg = small_cfg()
    text, args, outs = build_train(cfg, batch=4)
    nl = cfg.num_lstm_layers
    nparams = 3 * nl + 2
    # params, m, v, step, lr, xs, ys, masks.
    assert len(args) == 3 * nparams + 2 + 1 + 1 + 2 * nl
    assert args[-2 * nl - 1]["name"] == "ys"
    assert args[-2 * nl - 1]["dtype"] == "i32"
    # Outputs: params', m', v', step', loss.
    assert len(outs) == 3 * nparams + 2
    assert outs[-1]["name"] == "loss"
    assert "ENTRY" in text


def test_anomaly_train_has_no_labels():
    cfg = ArchConfig("anomaly", 4, 1, "NN", seq_len=10)
    _, args, _ = build_train(cfg, batch=4)
    assert not any(a["name"] == "ys" for a in args)


def test_lowered_forward_executes_in_jax():
    """The lowered computation compiles and runs under jax itself."""
    cfg = small_cfg()
    from compile.model import init_params, sample_masks, forward
    import jax.numpy as jnp

    params = init_params(cfg, jax.random.PRNGKey(0))
    masks = sample_masks(cfg, 3, jax.random.PRNGKey(1))
    xs = jnp.zeros((3, cfg.seq_len, 1))
    probs = jax.jit(lambda *a: forward(cfg, list(a[:5]), a[5], list(a[6:])))(
        *params, xs, *masks
    )
    assert probs.shape == (3, 4)
