# GRU Pallas kernel vs pure-jnp oracle (the paper's "other recurrent
# units" extension; Rust mirrors in rust/src/{nn,fpga}/gru.rs).

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.gru import (gru_cell, gru_cell_ref, gru_layer,
                                 GRU_GATES)

RTOL, ATOL = 1e-5, 1e-5


def _inputs(rng, n, idim, hdim, p=0.125):
    x = jnp.asarray(rng.standard_normal((n, idim)).astype(np.float32))
    h = jnp.asarray(
        (rng.standard_normal((n, hdim)) * 0.5).astype(np.float32))
    wx = jnp.asarray(
        (rng.standard_normal((GRU_GATES, idim, hdim)) * 0.3)
        .astype(np.float32))
    wh = jnp.asarray(
        (rng.standard_normal((GRU_GATES, hdim, hdim)) * 0.3)
        .astype(np.float32))
    b = jnp.asarray(
        (rng.standard_normal((GRU_GATES, hdim)) * 0.1).astype(np.float32))
    zx = jnp.asarray(
        (rng.uniform(size=(n, GRU_GATES, idim)) > p).astype(np.float32))
    zh = jnp.asarray(
        (rng.uniform(size=(n, GRU_GATES, hdim)) > p).astype(np.float32))
    return x, h, wx, wh, b, zx, zh


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 6), idim=st.integers(1, 8), hdim=st.integers(1, 10),
       seed=st.integers(0, 2**16))
def test_gru_cell_matches_ref(n, idim, hdim, seed):
    rng = np.random.default_rng(seed)
    args = _inputs(rng, n, idim, hdim)
    got = gru_cell(*args)
    want = gru_cell_ref(*args)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_gru_layer_shape_and_bound():
    rng = np.random.default_rng(4)
    x, h, wx, wh, b, zx, zh = _inputs(rng, 3, 2, 5)
    xs = jnp.asarray(rng.standard_normal((3, 9, 2)).astype(np.float32))
    hs = gru_layer(xs, wx, wh, b, zx, zh)
    assert hs.shape == (3, 9, 5)
    # Convex combination of tanh values: |h| <= 1.
    assert np.all(np.abs(np.asarray(hs)) <= 1.0 + 1e-5)


def test_gru_update_gate_interpolates():
    """With z -> 1 (huge update-gate bias) the state barely moves."""
    rng = np.random.default_rng(5)
    x, h, wx, wh, b, zx, zh = _inputs(rng, 2, 3, 4, p=0.0)
    b_frozen = b.at[1].set(50.0)  # z ~ 1
    h2 = gru_cell(x, h, wx, wh, b_frozen, zx, zh)
    np.testing.assert_allclose(h2, h, rtol=1e-3, atol=1e-3)
