# L2 model tests: shapes, architecture wiring, Bayesian mask plumbing,
# and a tiny end-to-end training check (loss decreases).

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (ArchConfig, init_params, param_names, mask_shapes,
                           ones_masks, sample_masks, forward, forward_logits,
                           loss_fn, train_step)

AE = ArchConfig("anomaly", 8, 1, "NN", seq_len=20)
AE_BAYES = ArchConfig("anomaly", 8, 2, "YNYN", seq_len=20)
CLS = ArchConfig("classify", 8, 2, "YN", seq_len=20)


def _data(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.standard_normal(
        (n, cfg.seq_len, cfg.input_dim)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, cfg.num_classes, n).astype(np.int32))
    return xs, ys


def test_lstm_dims_autoencoder():
    cfg = ArchConfig("anomaly", 16, 2, "YNYN")
    assert cfg.lstm_dims() == [(1, 16), (16, 8), (8, 16), (16, 16)]
    assert cfg.dense_dims() == (16, 1)
    assert cfg.num_lstm_layers == 4


def test_lstm_dims_autoencoder_nl1():
    cfg = ArchConfig("anomaly", 8, 1, "NN")
    assert cfg.lstm_dims() == [(1, 4), (4, 8)]


def test_lstm_dims_classifier():
    cfg = ArchConfig("classify", 8, 3, "YNY")
    assert cfg.lstm_dims() == [(1, 8), (8, 8), (8, 8)]
    assert cfg.dense_dims() == (8, 4)


def test_bad_bayes_pattern_rejected():
    with pytest.raises(AssertionError):
        ArchConfig("classify", 8, 2, "Y")       # wrong length
    with pytest.raises(AssertionError):
        ArchConfig("classify", 8, 1, "X")       # bad flag
    with pytest.raises(AssertionError):
        ArchConfig("anomaly", 7, 1, "NN")       # odd H has no H/2


def test_param_shapes_and_names():
    params = init_params(AE_BAYES, jax.random.PRNGKey(0))
    names = param_names(AE_BAYES)
    assert len(params) == len(names) == 3 * 4 + 2
    assert params[0].shape == (4, 1, 8)      # lstm0.wx (H=8)
    assert params[1].shape == (4, 8, 8)      # lstm0.wh
    assert params[-2].shape == (8, 1)        # dense.w
    # Forget-gate bias init = 1.
    assert np.allclose(params[2][1], 1.0)
    assert np.allclose(params[2][0], 0.0)


def test_forward_shapes_autoencoder():
    params = init_params(AE, jax.random.PRNGKey(0))
    xs, _ = _data(AE, 3)
    out = forward(AE, params, xs, ones_masks(AE, 3))
    assert out.shape == (3, AE.seq_len, 1)


def test_forward_shapes_classifier():
    params = init_params(CLS, jax.random.PRNGKey(0))
    xs, _ = _data(CLS, 5)
    probs = forward(CLS, params, xs, sample_masks(CLS, 5,
                                                  jax.random.PRNGKey(1)))
    assert probs.shape == (5, 4)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, rtol=1e-5)
    assert np.all(np.asarray(probs) >= 0)


def test_mask_shapes_cover_all_layers():
    shapes = mask_shapes(AE_BAYES, 7)
    assert len(shapes) == 2 * AE_BAYES.num_lstm_layers
    assert shapes[0] == (7, 4, 1)       # zx of first encoder layer
    assert shapes[1] == (7, 4, 8)       # zh (H=8)


def test_sample_masks_respect_bayes_pattern():
    key = jax.random.PRNGKey(0)
    masks = sample_masks(AE_BAYES, 64, key)
    # Layer 1 (N) must be all ones; layer 0 (Y) must contain zeros.
    assert np.all(np.asarray(masks[2]) == 1.0)
    assert np.all(np.asarray(masks[3]) == 1.0)
    m0 = np.asarray(masks[1])  # zh of layer 0 is large enough to hit zeros
    frac_zero = 1.0 - m0.mean()
    assert 0.05 < frac_zero < 0.25   # ~p = 0.125


def test_mc_samples_disagree_only_when_bayesian():
    """With MCD enabled, different masks must produce different outputs;
    pointwise (ones) must be deterministic."""
    params = init_params(CLS, jax.random.PRNGKey(0))
    xs, _ = _data(CLS, 1)
    xs2 = jnp.repeat(xs, 2, axis=0)
    p_mc = forward(CLS, params, xs2,
                   sample_masks(CLS, 2, jax.random.PRNGKey(5)))
    assert not np.allclose(p_mc[0], p_mc[1])
    p_det = forward(CLS, params, xs2, ones_masks(CLS, 2))
    np.testing.assert_allclose(p_det[0], p_det[1], rtol=1e-6)


def test_loss_finite_and_positive():
    params = init_params(CLS, jax.random.PRNGKey(0))
    xs, ys = _data(CLS, 4)
    l = loss_fn(CLS, params, xs, ys, ones_masks(CLS, 4))
    assert np.isfinite(float(l)) and float(l) > 0


@pytest.mark.parametrize("cfg,task", [(AE, "anomaly"), (CLS, "classify")])
def test_train_step_decreases_loss(cfg, task):
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jnp.float32(0.0)
    xs, ys = _data(cfg, 8)
    masks = ones_masks(cfg, 8)
    losses = []
    jitted = jax.jit(lambda p, m, v, s: train_step(
        cfg, 1e-2, p, m, v, s, xs, ys if task == "classify" else None,
        masks))
    for _ in range(30):
        params, m, v, step, loss = jitted(params, m, v, step)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses
    # And it should be decreasing early on, not oscillating.
    assert losses[5] < losses[0], losses[:6]


def test_grad_clip_bounds_update():
    """With a huge lr=0 step the params must not change; sanity of the
    train_step state plumbing."""
    params = init_params(CLS, jax.random.PRNGKey(0))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    xs, ys = _data(CLS, 4)
    new_p, _, _, step, loss = train_step(
        CLS, 0.0, params, m, v, jnp.float32(0.0), xs, ys,
        ones_masks(CLS, 4))
    assert float(step) == 1.0
    for p0, p1 in zip(params, new_p):
        np.testing.assert_allclose(p0, p1, rtol=1e-6)
