# L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).
# hypothesis sweeps shapes/seeds; assert_allclose is the CORE signal.

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lstm_cell, lstm_layer, dense, temporal_dense
from compile.kernels.ref import (lstm_cell_ref, lstm_layer_ref, dense_ref,
                                 GATES)

RTOL, ATOL = 1e-5, 1e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _cell_inputs(rng, n, idim, hdim, p=0.125):
    x = _rand(rng, n, idim)
    h = _rand(rng, n, hdim)
    c = _rand(rng, n, hdim)
    wx = _rand(rng, GATES, idim, hdim) * 0.3
    wh = _rand(rng, GATES, hdim, hdim) * 0.3
    b = _rand(rng, GATES, hdim) * 0.1
    zx = jnp.asarray(
        (rng.uniform(size=(n, GATES, idim)) > p).astype(np.float32))
    zh = jnp.asarray(
        (rng.uniform(size=(n, GATES, hdim)) > p).astype(np.float32))
    return x, h, c, wx, wh, b, zx, zh


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), idim=st.integers(1, 9), hdim=st.integers(1, 12),
       seed=st.integers(0, 2**16))
def test_cell_matches_ref(n, idim, hdim, seed):
    rng = np.random.default_rng(seed)
    args = _cell_inputs(rng, n, idim, hdim)
    h2, c2 = lstm_cell(*args)
    h2r, c2r = lstm_cell_ref(*args)
    np.testing.assert_allclose(h2, h2r, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(c2, c2r, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("block_n", [None, 2, 4])
def test_cell_block_tiling_invariant(block_n):
    """N-tiling (the VMEM reuse-factor analogue) must not change numerics."""
    rng = np.random.default_rng(7)
    args = _cell_inputs(rng, 8, 5, 6)
    h_full, c_full = lstm_cell(*args, block_n=None)
    h_t, c_t = lstm_cell(*args, block_n=block_n)
    np.testing.assert_allclose(h_t, h_full, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(c_t, c_full, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 4), t=st.integers(1, 12), idim=st.integers(1, 4),
       hdim=st.integers(1, 8), seed=st.integers(0, 2**16))
def test_layer_matches_ref(n, t, idim, hdim, seed):
    rng = np.random.default_rng(seed)
    xs = _rand(rng, n, t, idim)
    _, _, _, wx, wh, b, zx, zh = _cell_inputs(rng, n, idim, hdim)
    hs = lstm_layer(xs, wx, wh, b, zx, zh)
    hs_r = lstm_layer_ref(xs, wx, wh, b, zx, zh)
    assert hs.shape == (n, t, hdim)
    np.testing.assert_allclose(hs, hs_r, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 16), fdim=st.integers(1, 16), odim=st.integers(1, 8),
       seed=st.integers(0, 2**16))
def test_dense_matches_ref(n, fdim, odim, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, fdim)
    w = _rand(rng, fdim, odim)
    b = _rand(rng, odim)
    np.testing.assert_allclose(dense(x, w, b), dense_ref(x, w, b),
                               rtol=RTOL, atol=ATOL)


def test_temporal_dense_shares_weights_across_time():
    rng = np.random.default_rng(3)
    hs = _rand(rng, 2, 5, 4)
    w = _rand(rng, 4, 1)
    b = _rand(rng, 1)
    out = temporal_dense(hs, w, b)
    assert out.shape == (2, 5, 1)
    for t in range(5):
        np.testing.assert_allclose(out[:, t], dense_ref(hs[:, t], w, b),
                                   rtol=RTOL, atol=ATOL)


def test_mask_zero_kills_feature():
    """A zero dropout mask on gate g must remove that feature's
    contribution to gate g only (DX semantics, Sec. II-B)."""
    rng = np.random.default_rng(11)
    x, h, c, wx, wh, b, zx, zh = _cell_inputs(rng, 1, 3, 4, p=0.0)
    # Zero the input-gate (g=0) mask for input feature 0.
    zx0 = zx.at[0, 0, 0].set(0.0)
    h_a, _ = lstm_cell(x, h, c, wx, wh, b, zx0, zh)
    # Equivalent: zero the weight row instead.
    wx0 = wx.at[0, 0, :].set(0.0)
    h_b, _ = lstm_cell(x, h, c, wx0, wh, b, zx, zh)
    np.testing.assert_allclose(h_a, h_b, rtol=RTOL, atol=ATOL)


def test_all_ones_mask_is_pointwise():
    """Ones masks = the non-Bayesian (pointwise) LSTM."""
    rng = np.random.default_rng(13)
    x, h, c, wx, wh, b, _, _ = _cell_inputs(rng, 4, 3, 5)
    ones_x = jnp.ones((4, GATES, 3))
    ones_h = jnp.ones((4, GATES, 5))
    h2, c2 = lstm_cell(x, h, c, wx, wh, b, ones_x, ones_h)
    h2r, c2r = lstm_cell_ref(x, h, c, wx, wh, b, ones_x, ones_h)
    np.testing.assert_allclose(h2, h2r, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(c2, c2r, rtol=RTOL, atol=ATOL)


def test_cell_states_bounded():
    """|h| <= 1 by construction (sigmoid * tanh); c bounded by f*c + i*g."""
    rng = np.random.default_rng(17)
    args = _cell_inputs(rng, 6, 4, 7)
    h2, c2 = lstm_cell(*args)
    assert np.all(np.abs(np.asarray(h2)) <= 1.0 + 1e-6)
    c_prev = np.asarray(args[2])
    assert np.all(np.abs(np.asarray(c2)) <= np.abs(c_prev).max() + 1.0 + 1e-6)


def test_jit_and_eager_agree():
    rng = np.random.default_rng(19)
    args = _cell_inputs(rng, 3, 2, 4)
    h_e, c_e = lstm_cell(*args)
    h_j, c_j = jax.jit(lstm_cell)(*args)
    np.testing.assert_allclose(h_j, h_e, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(c_j, c_e, rtol=RTOL, atol=ATOL)
