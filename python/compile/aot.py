# AOT driver: lower every needed (architecture, entrypoint, batch) variant
# to HLO *text* plus a manifest.json the Rust runtime consumes.
#
# HLO text — NOT lowered.compile()/.serialize() — is the interchange format:
# jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
# xla_extension 0.5.1 (the version behind the published `xla` 0.1.6 crate)
# rejects; the text parser reassigns ids and round-trips cleanly.
# See /opt/xla-example/gen_hlo.py.
#
# Python runs ONCE at build time (`make artifacts`); the Rust binary is
# self-contained afterwards.

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (ArchConfig, init_params, param_names, mask_shapes,
                    forward, train_step)

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _arg(name, shape, dtype=F32):
    return {"name": name, "shape": list(shape), "dtype": dtype}


# --------------------------------------------------------------------------
# Entrypoint builders. Each returns (hlo_text, args_meta, outputs_meta).
# Argument order is positional and mirrored exactly by the Rust runtime.
# --------------------------------------------------------------------------

def build_forward(cfg: ArchConfig, n: int):
    """fwd(params..., xs [n,T,I], masks...) -> (y,)"""
    pshapes = [p.shape for p in init_params(cfg, jax.random.PRNGKey(0))]
    mshapes = mask_shapes(cfg, n)
    nparams = len(pshapes)

    def fn(*flat):
        params = list(flat[:nparams])
        xs = flat[nparams]
        masks = list(flat[nparams + 1:])
        return (forward(cfg, params, xs, masks),)

    specs = ([_spec(s) for s in pshapes]
             + [_spec((n, cfg.seq_len, cfg.input_dim))]
             + [_spec(s) for s in mshapes])
    lowered = jax.jit(fn).lower(*specs)
    args = ([_arg(nm, s) for nm, s in zip(param_names(cfg), pshapes)]
            + [_arg("xs", (n, cfg.seq_len, cfg.input_dim))]
            + [_arg(f"mask{i}", s) for i, s in enumerate(mshapes)])
    if cfg.task == "anomaly":
        outs = [_arg("recon", (n, cfg.seq_len, cfg.input_dim))]
    else:
        outs = [_arg("probs", (n, cfg.num_classes))]
    return to_hlo_text(lowered), args, outs


def build_train(cfg: ArchConfig, batch: int):
    """train(params..., m..., v..., step, lr, xs, [ys,] masks...)
    -> (params'..., m'..., v'..., step', loss)"""
    pshapes = [p.shape for p in init_params(cfg, jax.random.PRNGKey(0))]
    mshapes = mask_shapes(cfg, batch)
    nparams = len(pshapes)
    has_labels = cfg.task == "classify"

    def fn(*flat):
        i = 0
        params = list(flat[i:i + nparams]); i += nparams
        m = list(flat[i:i + nparams]); i += nparams
        v = list(flat[i:i + nparams]); i += nparams
        step = flat[i]; i += 1
        lr = flat[i]; i += 1
        xs = flat[i]; i += 1
        if has_labels:
            ys = flat[i]; i += 1
        else:
            ys = None
        masks = list(flat[i:])
        new_p, new_m, new_v, new_step, loss = train_step(
            cfg, lr, params, m, v, step, xs, ys, masks)
        return tuple(new_p + new_m + new_v + [new_step, loss])

    specs = ([_spec(s) for s in pshapes] * 3
             + [_spec(()), _spec(())]
             + [_spec((batch, cfg.seq_len, cfg.input_dim))]
             + ([_spec((batch,), jnp.int32)] if has_labels else [])
             + [_spec(s) for s in mshapes])
    lowered = jax.jit(fn).lower(*specs)
    pn = param_names(cfg)
    args = ([_arg(nm, s) for nm, s in zip(pn, pshapes)]
            + [_arg("m." + nm, s) for nm, s in zip(pn, pshapes)]
            + [_arg("v." + nm, s) for nm, s in zip(pn, pshapes)]
            + [_arg("step", ()), _arg("lr", ())]
            + [_arg("xs", (batch, cfg.seq_len, cfg.input_dim))]
            + ([_arg("ys", (batch,), I32)] if has_labels else [])
            + [_arg(f"mask{i}", s) for i, s in enumerate(mshapes)])
    outs = ([_arg(nm, s) for nm, s in zip(pn, pshapes)]
            + [_arg("m." + nm, s) for nm, s in zip(pn, pshapes)]
            + [_arg("v." + nm, s) for nm, s in zip(pn, pshapes)]
            + [_arg("step", ()), _arg("loss", ())])
    return to_hlo_text(lowered), args, outs


# --------------------------------------------------------------------------
# The default artifact set: the paper's named architectures (Tables III-VI)
# plus batch variants used by the platform-comparison bench. `--full` adds
# the complete DSE sweep grid (slower to lower; the DSE sweep itself trains
# through the native Rust engine and does not need per-config HLO).
# --------------------------------------------------------------------------

DEFAULT_CONFIGS = [
    # (cfg, fwd batch rows N list, train batch list)
    (ArchConfig("anomaly", 16, 2, "YNYN"), [1, 30], [64]),   # Table V best
    (ArchConfig("anomaly", 16, 2, "NNNN"), [1, 30], [64]),   # pointwise twin
    (ArchConfig("anomaly", 8, 1, "NN"),    [1, 30], [64]),   # Opt-Latency
    (ArchConfig("classify", 8, 3, "YNY"),  [1, 30], [64]),   # Table VI best
    (ArchConfig("classify", 8, 3, "NYN"),  [1, 30], [64]),   # Opt-Accuracy
    (ArchConfig("classify", 8, 3, "YNN"),  [1, 30], [64]),   # Opt-Entropy
    (ArchConfig("classify", 8, 2, "YN"),   [1, 30], [64]),   # Opt-Recall
    (ArchConfig("classify", 8, 1, "N"),    [1, 30], [64]),   # Opt-Latency
]

# Large-row fwd variants for the Table IV CPU/GPU batch sweep (batch x S).
BATCH_VARIANTS = {
    "anomaly_h16_nl2_YNYN": [1500, 6000],   # 50*30, 200*30
    "classify_h8_nl3_YNY": [1500, 6000],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also lower the complete DSE sweep grid")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}

    configs = list(DEFAULT_CONFIGS)
    if args.full:
        for h in (8, 16, 24, 32):
            for nl in (1, 2):
                for bpat in {"Y" * 2 * nl, "N" * 2 * nl}:
                    c = ArchConfig("anomaly", h, nl, bpat)
                    if not any(x[0].name == c.name for x in configs):
                        configs.append((c, [30], []))

    for cfg, fwd_ns, train_bs in configs:
        fwd_ns = list(fwd_ns) + BATCH_VARIANTS.get(cfg.name, [])
        for n in fwd_ns:
            fname = f"{cfg.name}.fwd_n{n}.hlo.txt"
            text, a, o = build_forward(cfg, n)
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append({
                "name": f"{cfg.name}.fwd_n{n}", "file": fname,
                "kind": "forward", "task": cfg.task, "hidden": cfg.hidden,
                "nl": cfg.nl, "bayes": cfg.bayes, "rows": n,
                "seq_len": cfg.seq_len, "input_dim": cfg.input_dim,
                "num_classes": cfg.num_classes, "args": a, "outputs": o,
            })
            print(f"lowered {fname} ({len(text)} chars)")
        for b in train_bs:
            fname = f"{cfg.name}.train_b{b}.hlo.txt"
            text, a, o = build_train(cfg, b)
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append({
                "name": f"{cfg.name}.train_b{b}", "file": fname,
                "kind": "train", "task": cfg.task, "hidden": cfg.hidden,
                "nl": cfg.nl, "bayes": cfg.bayes, "rows": b,
                "seq_len": cfg.seq_len, "input_dim": cfg.input_dim,
                "num_classes": cfg.num_classes, "args": a, "outputs": o,
            })
            print(f"lowered {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
