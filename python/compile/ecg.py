# Synthetic ECG5000 equivalent (see DESIGN.md §Substitutions).
#
# ECG5000 (PhysioNet / UCR) is 5000 single heartbeats of length T=140,
# z-normalised, 1 normal class + 3 anomalous classes, with a tiny 500-beat
# training split and heavy class imbalance. We have no network access to
# PhysioNet, so this module generates a deterministic synthetic pool with
# the same statistical role: Gaussian-bump P-QRS-T morphologies where
# reconstruction error separates normal from anomalous beats and MCD
# uncertainty inflates on anomalies.
#
# The Rust data substrate (rust/src/data/) implements the *same generator*
# (same class mixture, same morphology parameters); python/tests checks the
# two agree statistically. Python uses this only for build-time tests.

import numpy as np

T = 140
CLASSES = 4
# Class mixture mirroring ECG5000's imbalance (normal ~58%).
CLASS_PROBS = np.array([0.584, 0.310, 0.070, 0.036])
TRAIN_N, TEST_N = 500, 4500


def _bump(t, center, width, amp):
    return amp * np.exp(-0.5 * ((t - center) / width) ** 2)


def _beat(rng, label):
    """One beat of length T for class `label` (0 = normal)."""
    t = np.arange(T, dtype=np.float64)
    # Per-beat jitter on landmark positions/amplitudes.
    j = lambda s: rng.normal(0.0, s)  # noqa: E731
    p_c, q_c, r_c, s_c, t_c = (25 + j(2), 55 + j(1.5), 62 + j(1.5),
                               69 + j(1.5), 105 + j(3))
    sig = (_bump(t, p_c, 4.0, 0.18 + j(0.02))        # P wave
           + _bump(t, q_c, 1.8, -0.28 + j(0.03))     # Q
           + _bump(t, r_c, 2.2, 1.60 + j(0.08))      # R
           + _bump(t, s_c, 2.0, -0.45 + j(0.04))     # S
           + _bump(t, t_c, 9.0, 0.45 + j(0.04)))     # T wave
    if label == 1:
        # R-on-T / PVC-like: inverted, widened T and depressed ST segment.
        sig -= 2.1 * _bump(t, t_c, 11.0, 0.55 + j(0.05))
        sig -= 0.25 * _bump(t, (s_c + t_c) / 2, 12.0, 1.0)
    elif label == 2:
        # Supraventricular-like: flattened R, early weak T.
        sig -= _bump(t, r_c, 2.2, 0.95 + j(0.06))
        sig -= 0.5 * _bump(t, t_c, 9.0, 0.45)
        sig += _bump(t, t_c - 18, 7.0, 0.22 + j(0.03))
    elif label == 3:
        # Premature/ectopic-like: whole complex time-warped earlier + drift.
        shift = int(12 + abs(j(3)))
        sig = np.roll(sig, -shift)
        sig += 0.15 * np.sin(2 * np.pi * t / T + j(0.5))
    sig += rng.normal(0.0, 0.05, T)  # sensor noise
    # Per-sample z-normalisation (the dataset's preprocessing).
    sig = (sig - sig.mean()) / (sig.std() + 1e-8)
    return sig.astype(np.float32)


def generate(n, seed=0):
    """Return (x [n, T, 1] float32, y [n] int32)."""
    rng = np.random.RandomState(seed)
    labels = rng.choice(CLASSES, size=n, p=CLASS_PROBS).astype(np.int32)
    x = np.stack([_beat(rng, int(lb)) for lb in labels])[:, :, None]
    return x, labels


def splits(seed=0):
    """The paper's split: 500 train / 4500 test."""
    x, y = generate(TRAIN_N + TEST_N, seed=seed)
    return (x[:TRAIN_N], y[:TRAIN_N]), (x[TRAIN_N:], y[TRAIN_N:])
