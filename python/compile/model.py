# L2: the paper's recurrent architectures (Sec. III-C) in JAX, built on the
# L1 Pallas kernels. Build-time only — lowered to HLO text by aot.py and
# executed from Rust; never imported on the request path.
#
# Two topologies, both parameterised by A = {H, NL, B}:
#   * recurrent autoencoder (anomaly detection): NL encoder LSTMs (the last
#     one has hidden H/2 — the bottleneck), NL decoder LSTMs (hidden H) fed
#     the bottleneck h_T repeated T times, then a temporal dense H -> I
#     reconstructing the input;
#   * recurrent classifier: NL LSTMs (hidden H), dense H -> O on the final
#     hidden state, softmax.
#
# B is a Y/N string with one flag per LSTM layer (2*NL for the autoencoder,
# NL for the classifier): Y => MC-dropout masks are applied to that layer's
# per-gate x/h copies. Masks are *inputs* to every lowered function — the
# Rust coordinator samples them (its LFSR Bernoulli sampler) and passes all
# layers' masks; non-Bayesian layers simply receive ones. This keeps one
# HLO signature per architecture shape regardless of B.

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import lstm_layer, dense, temporal_dense

GATES = 4


@dataclass(frozen=True)
class ArchConfig:
    """Architecture point A = {H, NL, B} plus task constants."""

    task: str          # "anomaly" | "classify"
    hidden: int        # H
    nl: int            # NL: LSTM count in encoder (and decoder for AE)
    bayes: str         # Y/N per LSTM layer; len == num_lstm_layers
    input_dim: int = 1     # I (ECG is univariate)
    seq_len: int = 140     # T
    num_classes: int = 4   # O for the classifier
    dropout_p: float = 0.125  # paper fixes p = 1/8 (3 LFSRs + NAND)

    def __post_init__(self):
        assert self.task in ("anomaly", "classify"), self.task
        assert len(self.bayes) == self.num_lstm_layers, (
            f"B pattern {self.bayes!r} must have {self.num_lstm_layers} flags"
        )
        assert set(self.bayes) <= {"Y", "N"}, self.bayes
        if self.task == "anomaly":
            assert self.hidden % 2 == 0, "bottleneck is H/2"

    @property
    def num_lstm_layers(self) -> int:
        return 2 * self.nl if self.task == "anomaly" else self.nl

    @property
    def bottleneck(self) -> int:
        return self.hidden // 2

    def lstm_dims(self) -> List[Tuple[int, int]]:
        """(input_dim, hidden_dim) for every LSTM layer, in order."""
        dims = []
        if self.task == "anomaly":
            # Encoder: I -> H -> ... -> H/2 (last layer is the bottleneck).
            prev = self.input_dim
            for l in range(self.nl):
                h = self.bottleneck if l == self.nl - 1 else self.hidden
                dims.append((prev, h))
                prev = h
            # Decoder: H/2 -> H -> ... -> H.
            for _ in range(self.nl):
                dims.append((prev, self.hidden))
                prev = self.hidden
        else:
            prev = self.input_dim
            for _ in range(self.nl):
                dims.append((prev, self.hidden))
                prev = self.hidden
        return dims

    def dense_dims(self) -> Tuple[int, int]:
        if self.task == "anomaly":
            return (self.hidden, self.input_dim)   # temporal reconstruction
        return (self.hidden, self.num_classes)

    @property
    def name(self) -> str:
        return f"{self.task}_h{self.hidden}_nl{self.nl}_{self.bayes}"


# --------------------------------------------------------------------------
# Parameters. Layout (also the flattening order consumed by Rust — see
# aot.py manifest): for each LSTM layer l in order: wx[l] [4,I_l,H_l],
# wh[l] [4,H_l,H_l], b[l] [4,H_l]; then dense w [F,O], dense b [O].
# --------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key) -> List[jnp.ndarray]:
    params = []
    for (idim, hdim) in cfg.lstm_dims():
        key, kx, kh = jax.random.split(key, 3)
        sx = (6.0 / (idim + hdim)) ** 0.5   # Glorot-uniform
        sh = (6.0 / (hdim + hdim)) ** 0.5
        params.append(jax.random.uniform(kx, (GATES, idim, hdim),
                                         minval=-sx, maxval=sx))
        params.append(jax.random.uniform(kh, (GATES, hdim, hdim),
                                         minval=-sh, maxval=sh))
        b = jnp.zeros((GATES, hdim))
        # Forget-gate bias = 1.0 (standard LSTM training aid).
        b = b.at[1].set(1.0)
        params.append(b)
    fdim, odim = cfg.dense_dims()
    key, kd = jax.random.split(key)
    sd = (6.0 / (fdim + odim)) ** 0.5
    params.append(jax.random.uniform(kd, (fdim, odim), minval=-sd, maxval=sd))
    params.append(jnp.zeros((odim,)))
    return params


def param_names(cfg: ArchConfig) -> List[str]:
    names = []
    for l in range(cfg.num_lstm_layers):
        names += [f"lstm{l}.wx", f"lstm{l}.wh", f"lstm{l}.b"]
    names += ["dense.w", "dense.b"]
    return names


def mask_shapes(cfg: ArchConfig, n: int) -> List[Tuple[int, ...]]:
    """Shapes of the per-layer mask inputs (zx then zh per layer)."""
    shapes = []
    for (idim, hdim) in cfg.lstm_dims():
        shapes.append((n, GATES, idim))
        shapes.append((n, GATES, hdim))
    return shapes


def ones_masks(cfg: ArchConfig, n: int) -> List[jnp.ndarray]:
    return [jnp.ones(s, jnp.float32) for s in mask_shapes(cfg, n)]


def sample_masks(cfg: ArchConfig, n: int, key) -> List[jnp.ndarray]:
    """Bernoulli(1-p) masks for Bayesian layers, ones elsewhere.

    Python-side analogue of the Rust LFSR sampler; used in training tests
    and algorithmic pytest checks.
    """
    masks = []
    for l, (idim, hdim) in enumerate(cfg.lstm_dims()):
        for shape in ((n, GATES, idim), (n, GATES, hdim)):
            if cfg.bayes[l] == "Y":
                key, k = jax.random.split(key)
                masks.append(
                    jax.random.bernoulli(k, 1.0 - cfg.dropout_p, shape)
                    .astype(jnp.float32))
            else:
                masks.append(jnp.ones(shape, jnp.float32))
    return masks


# --------------------------------------------------------------------------
# Forward passes.
# --------------------------------------------------------------------------

def _run_lstm_stack(cfg, params, masks, xs, layers):
    """Run LSTM layers `layers` (iterable of indices) over xs [N,T,*]."""
    out = xs
    for l in layers:
        wx, wh, b = params[3 * l], params[3 * l + 1], params[3 * l + 2]
        zx, zh = masks[2 * l], masks[2 * l + 1]
        out = lstm_layer(out, wx, wh, b, zx, zh)
    return out


def forward(cfg: ArchConfig, params, xs, masks):
    """Model forward. xs [N,T,I] -> AE: recon [N,T,I]; cls: probs [N,O]."""
    nl = cfg.nl
    if cfg.task == "anomaly":
        enc = _run_lstm_stack(cfg, params, masks, xs, range(nl))
        # Bottleneck: last hidden state of last encoder LSTM, repeated T
        # times (the paper caches it for exactly T steps).
        emb = enc[:, -1, :]                       # [N, H/2]
        rep = jnp.repeat(emb[:, None, :], cfg.seq_len, axis=1)
        dec = _run_lstm_stack(cfg, params, masks, rep, range(nl, 2 * nl))
        w, b = params[-2], params[-1]
        return temporal_dense(dec, w, b)          # [N, T, I]
    else:
        enc = _run_lstm_stack(cfg, params, masks, xs, range(nl))
        h_t = enc[:, -1, :]                       # [N, H]
        w, b = params[-2], params[-1]
        logits = dense(h_t, w, b)
        return jax.nn.softmax(logits, axis=-1)    # [N, O]


def forward_logits(cfg: ArchConfig, params, xs, masks):
    """Classifier logits (for the training loss)."""
    assert cfg.task == "classify"
    enc = _run_lstm_stack(cfg, params, masks, xs, range(cfg.nl))
    return dense(enc[:, -1, :], params[-2], params[-1])


# --------------------------------------------------------------------------
# Loss + Adam train step (grad-clip 3.0, decoupled weight decay 1e-4 — the
# paper's training recipe). Lowered per-architecture by aot.py; the Rust
# training loop owns the outer epoch loop and the MCD mask sampling.
# --------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
GRAD_CLIP = 3.0
WEIGHT_DECAY = 1e-4


def loss_fn(cfg: ArchConfig, params, xs, ys, masks):
    if cfg.task == "anomaly":
        recon = forward(cfg, params, xs, masks)
        return jnp.mean((recon - xs) ** 2)
    logits = forward_logits(cfg, params, xs, masks)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(ys, cfg.num_classes)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def train_step(cfg: ArchConfig, lr: float,
               params, m, v, step, xs, ys, masks):
    """One AdamW step. All state in/out as tensor lists (PJRT-friendly).

    step is a float32 scalar step counter (pre-increment).
    Returns (new_params, new_m, new_v, new_step, loss).
    """
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, xs, ys, masks))(params)
    # Global-norm clipping at 3.0.
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, GRAD_CLIP / (gnorm + 1e-12))
    grads = [g * scale for g in grads]
    step = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** step
    bc2 = 1.0 - ADAM_B2 ** step
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1 - ADAM_B2) * g * g
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        p = p - lr * (upd + WEIGHT_DECAY * p)
        new_p.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, step, loss
