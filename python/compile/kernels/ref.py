# Pure-jnp correctness oracles for the Pallas kernels (L1).
#
# These implement the paper's Bayesian LSTM cell (Sec. II-A/II-B) exactly:
# the input x_t and hidden state h_{t-1} are *decoupled per gate* and each
# copy is masked by its own Bernoulli MC-dropout mask (z_x^g, z_h^g) before
# the gate matrix-vector multiply. Masks are sampled once per sequence
# (outside), so they arrive here as plain tensors.
#
# Shapes (N = MC-sample/batch rows folded together):
#   x  [N, I]      h, c [N, H]
#   wx [4, I, H]   wh   [4, H, H]   b [4, H]
#   zx [N, 4, I]   zh   [N, 4, H]
# Gate order along the leading axis of wx/wh/b/zx/zh: (i, f, g, o).

import jax
import jax.numpy as jnp

GATES = 4  # input, forget, modulation, output


def lstm_cell_ref(x, h, c, wx, wh, b, zx, zh):
    """One Bayesian LSTM cell step; returns (h_next, c_next)."""
    # pre[g] = (x * zx[:, g]) @ wx[g] + (h * zh[:, g]) @ wh[g] + b[g]
    pre = [
        (x * zx[:, g]) @ wx[g] + (h * zh[:, g]) @ wh[g] + b[g]
        for g in range(GATES)
    ]
    i = jax.nn.sigmoid(pre[0])
    f = jax.nn.sigmoid(pre[1])
    g_ = jnp.tanh(pre[2])
    o = jax.nn.sigmoid(pre[3])
    c_next = f * c + i * g_
    h_next = o * jnp.tanh(c_next)
    return h_next, c_next


def dense_ref(x, w, b):
    """Dense layer oracle: x [N, F] @ w [F, O] + b [O]."""
    return x @ w + b


def lstm_layer_ref(xs, wx, wh, b, zx, zh):
    """Scan the reference cell over time.

    xs [N, T, I] -> hs [N, T, H]. Masks are reused across all T steps
    (sampled once per sequence, per the paper).
    """
    n = xs.shape[0]
    hdim = wh.shape[1]
    h0 = jnp.zeros((n, hdim), xs.dtype)
    c0 = jnp.zeros((n, hdim), xs.dtype)

    def step(carry, x_t):
        h, c = carry
        h2, c2 = lstm_cell_ref(x_t, h, c, wx, wh, b, zx, zh)
        return (h2, c2), h2

    (_, _), hs = jax.lax.scan(step, (h0, c0), jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1)
