# L1 Pallas kernel: dense (single-MVM) layer.
#
# The paper implements the final dense layer as one MVM unit with its own
# reuse factor R_d; the temporal dense variant applies the same weights to
# every timestep of the decoder output (Sec. III-C). A single full block is
# used — the row dimension is what the MXU batches over; tiling hooks are
# in lstm.py where the footprint actually matters.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = x_ref[...] @ w_ref[...] + b_ref[...][None, :]


def _dense_pallas(x, w, b):
    n, fdim = x.shape
    odim = w.shape[1]
    return pl.pallas_call(
        _dense_kernel,
        out_shape=jax.ShapeDtypeStruct((n, odim), x.dtype),
        interpret=True,
    )(x, w, b)


# Pallas forward + oracle-VJP backward (same pattern as kernels/lstm.py —
# interpret-mode Pallas has no reverse-mode AD).
@jax.custom_vjp
def dense(x, w, b):
    """x [N,F] @ w [F,O] + b [O] -> [N,O]."""
    return _dense_pallas(x, w, b)


def _dense_fwd(x, w, b):
    return _dense_pallas(x, w, b), (x, w, b)


def _dense_bwd(res, ct):
    x, w, b = res
    _, vjp = jax.vjp(lambda x, w, b: x @ w + b, x, w, b)
    return vjp(ct)


dense.defvjp(_dense_fwd, _dense_bwd)


def temporal_dense(hs, w, b):
    """Apply the same dense weights to every timestep: [N,T,F] -> [N,T,O]."""
    n, t, fdim = hs.shape
    flat = hs.reshape(n * t, fdim)
    out = dense(flat, w, b)
    return out.reshape(n, t, w.shape[1])
