from .lstm import lstm_cell, lstm_layer  # noqa: F401
from .dense import dense, temporal_dense  # noqa: F401
from . import ref  # noqa: F401
