# L1 Pallas kernel: fused Bayesian GRU cell step (the paper's "similar
# design logic ... for other recurrent units such as the gated recurrent
# unit", Sec. III-A). Gate order (r, z, n); same per-gate MC-dropout
# decoupling as the LSTM kernel. Mirrored by rust/src/{nn,fpga}/gru.rs.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GRU_GATES = 3


def gru_cell_ref(x, h, wx, wh, b, zx, zh):
    """Pure-jnp oracle. x [N,I], h [N,H], wx [3,I,H], wh [3,H,H], b [3,H],
    zx [N,3,I], zh [N,3,H] -> h_next [N,H]."""
    xt = [(x * zx[:, g]) @ wx[g] + b[g] for g in range(GRU_GATES)]
    ht = [(h * zh[:, g]) @ wh[g] for g in range(GRU_GATES)]
    r = jax.nn.sigmoid(xt[0] + ht[0])
    z = jax.nn.sigmoid(xt[1] + ht[1])
    n = jnp.tanh(xt[2] + r * ht[2])
    return (1.0 - z) * n + z * h


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, b_ref, zx_ref, zh_ref, ho_ref):
    x = x_ref[...]
    h = h_ref[...]
    xm = x[:, None, :] * zx_ref[...]          # [N,3,I]
    hm = h[:, None, :] * zh_ref[...]          # [N,3,H]
    xt = jnp.einsum("ngi,gih->ngh", xm, wx_ref[...]) + b_ref[...][None]
    ht = jnp.einsum("ngh,ghk->ngk", hm, wh_ref[...])
    r = jax.nn.sigmoid(xt[:, 0] + ht[:, 0])
    z = jax.nn.sigmoid(xt[:, 1] + ht[:, 1])
    n = jnp.tanh(xt[:, 2] + r * ht[:, 2])
    ho_ref[...] = (1.0 - z) * n + z * h


def gru_cell(x, h, wx, wh, b, zx, zh):
    """Fused Bayesian GRU cell step via Pallas (interpret=True)."""
    n, _ = x.shape
    hdim = h.shape[1]
    return pl.pallas_call(
        _gru_kernel,
        out_shape=jax.ShapeDtypeStruct((n, hdim), x.dtype),
        interpret=True,
    )(x, h, wx, wh, b, zx, zh)


def gru_layer(xs, wx, wh, b, zx, zh):
    """Scan the fused GRU cell over T: xs [N,T,I] -> hs [N,T,H]."""
    n = xs.shape[0]
    hdim = wh.shape[1]
    h0 = jnp.zeros((n, hdim), xs.dtype)

    def step(h, x_t):
        h2 = gru_cell(x_t, h, wx, wh, b, zx, zh)
        return h2, h2

    _, hs = jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1)
