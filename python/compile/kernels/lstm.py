# L1 Pallas kernel: fused Bayesian LSTM cell step.
#
# This is the compute hot-spot of the paper's accelerator (Fig. 2): the four
# gate MVMs fed by DX-masked copies of x_t and h_{t-1}, followed by the
# element-wise LSTM tail. On the FPGA these are four parallel MVM engines
# plus a tail unit; here the whole cell step is one fused kernel so the
# lowered HLO keeps h/c resident and streams only x, and the dropout-mask
# multiply (the paper's DX demultiplexors) never materialises a masked copy
# outside the kernel.
#
# TPU adaptation (DESIGN.md §Hardware-Adaptation): rows N = MC-samples x
# requests are the analogue of the paper's sample-wise pipelining and map
# to the MXU batch dimension; weights live in VMEM for the whole T-loop
# like the paper's on-chip registers; `block_n` tiles N when a tile no
# longer fits VMEM (the reuse-factor trade-off of Sec. IV-B). On this CPU
# stack a single full block is optimal — a fine-grained grid would
# serialise rows inside the T-scan.
#
# interpret=True is mandatory on CPU PJRT — real TPU lowering emits a
# Mosaic custom-call the CPU plugin cannot execute.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

GATES = 4


def _cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, zx_ref, zh_ref,
                 ho_ref, co_ref):
    """Fused cell step over a [bn, ...] row tile.

    x [bn,I], h/c [bn,H], wx [4,I,H], wh [4,H,H], b [4,H],
    zx [bn,4,I], zh [bn,4,H] -> h',c' [bn,H].
    """
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    wx = wx_ref[...]
    wh = wh_ref[...]
    b = b_ref[...]
    # DX masking: per-gate decoupled copies of x and h (Sec. II-A/II-B).
    xm = x[:, None, :] * zx_ref[...]                  # [bn, 4, I]
    hm = h[:, None, :] * zh_ref[...]                  # [bn, 4, H]
    # Four gate MVM engines, batched on the MXU.
    pre = (jnp.einsum("ngi,gih->ngh", xm, wx)
           + jnp.einsum("ngh,ghk->ngk", hm, wh)
           + b[None])                                  # [bn, 4, H]
    i = jax.nn.sigmoid(pre[:, 0])
    f = jax.nn.sigmoid(pre[:, 1])
    g = jnp.tanh(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    # LSTM tail unit (the paper's 32-bit c-path).
    c2 = f * c + i * g
    h2 = o * jnp.tanh(c2)
    ho_ref[...] = h2
    co_ref[...] = c2


def lstm_cell(x, h, c, wx, wh, b, zx, zh, block_n=None):
    """Fused Bayesian LSTM cell step via Pallas.

    x [N,I], h/c [N,H], wx [4,I,H], wh [4,H,H], b [4,H],
    zx [N,4,I], zh [N,4,H]  ->  (h_next [N,H], c_next [N,H]).

    block_n: optional row-tile size (must divide N); None = one full block.
    """
    n, idim = x.shape
    hdim = h.shape[1]
    dt = x.dtype
    out_shape = [
        jax.ShapeDtypeStruct((n, hdim), dt),
        jax.ShapeDtypeStruct((n, hdim), dt),
    ]
    if block_n is None or block_n >= n:
        grid = ()
        bn = n
        row = None
    else:
        assert n % block_n == 0, (n, block_n)
        grid = (n // block_n,)
        bn = block_n
        row = lambda s: s  # noqa: E731

    def spec(shape, tiled):
        if not grid:
            return pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))
        if tiled:
            return pl.BlockSpec(shape,
                                lambda s: (s,) + tuple(0 for _ in shape[1:]))
        return pl.BlockSpec(shape, lambda s: tuple(0 for _ in shape))

    return pl.pallas_call(
        _cell_kernel,
        grid=grid,
        in_specs=[
            spec((bn, idim), True),             # x
            spec((bn, hdim), True),             # h
            spec((bn, hdim), True),             # c
            spec((GATES, idim, hdim), False),   # wx
            spec((GATES, hdim, hdim), False),   # wh
            spec((GATES, hdim), False),         # b
            spec((bn, GATES, idim), True),      # zx
            spec((bn, GATES, hdim), True),      # zh
        ],
        out_specs=[
            spec((bn, hdim), True),
            spec((bn, hdim), True),
        ],
        out_shape=out_shape,
        interpret=True,
    )(x, h, c, wx, wh, b, zx, zh)


# --------------------------------------------------------------------------
# Autodiff bridge. Pallas interpret-mode kernels do not support reverse-mode
# AD, so the train step (L2 bwd) differentiates through a custom_vjp whose
# forward IS the fused Pallas kernel and whose backward is the VJP of the
# pure-jnp oracle (ref.py), rematerialising the cell forward. The two
# forwards are asserted equal by python/tests/test_kernels.py, so the
# gradient is exact for the kernel as shipped.
# --------------------------------------------------------------------------

@jax.custom_vjp
def lstm_cell_ad(x, h, c, wx, wh, b, zx, zh):
    return lstm_cell(x, h, c, wx, wh, b, zx, zh)


def _cell_fwd(x, h, c, wx, wh, b, zx, zh):
    out = lstm_cell(x, h, c, wx, wh, b, zx, zh)
    return out, (x, h, c, wx, wh, b, zx, zh)


def _cell_bwd(res, cts):
    _, vjp = jax.vjp(_ref.lstm_cell_ref, *res)
    return vjp(cts)


lstm_cell_ad.defvjp(_cell_fwd, _cell_bwd)


def lstm_layer(xs, wx, wh, b, zx, zh, block_n=None):
    """Scan the fused cell over T. xs [N,T,I] -> hs [N,T,H].

    The scan carry (h, c) mirrors the paper's recurrent data dependency:
    layer i+1 can start as soon as one h_t is available (timestep
    pipelining, Fig. 5) — XLA expresses that as this layer's scan feeding
    the next layer's scan without materialising anything beyond hs.
    """
    n = xs.shape[0]
    hdim = wh.shape[1]
    h0 = jnp.zeros((n, hdim), xs.dtype)
    c0 = jnp.zeros((n, hdim), xs.dtype)

    def step(carry, x_t):
        h, c = carry
        if block_n is None:
            h2, c2 = lstm_cell_ad(x_t, h, c, wx, wh, b, zx, zh)
        else:
            h2, c2 = lstm_cell(x_t, h, c, wx, wh, b, zx, zh,
                               block_n=block_n)
        return (h2, c2), h2

    (_, _), hs = jax.lax.scan(step, (h0, c0), jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1)
