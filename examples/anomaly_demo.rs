//! Fig. 1 reproduction: reconstruct a normal and an anomalous ECG beat
//! with the Bayesian recurrent autoencoder and show the prediction with
//! +/-3 sigma uncertainty, NLL, L1 and RMSE — the paper's motivating
//! example.
//!
//!     cargo run --release --example anomaly_demo

use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::metrics;
use bayes_rnn_fpga::train::eval::ModelPredictor;
use bayes_rnn_fpga::train::{eval::Predictor, NativeTrainer, TrainOpts};

fn ascii_plot(target: &[f32], mean: &[f32], std: &[f32]) {
    // ASCII band plot: '.' target, 'o' mean, ':' the 3-sigma band.
    let rows = 14usize;
    let lo = -3.0f32;
    let hi = 3.0f32;
    let t = target.len();
    let cols = 70.min(t);
    let map = |v: f32| -> usize {
        let clamped = v.clamp(lo, hi - 1e-3);
        ((hi - clamped) / (hi - lo) * rows as f32) as usize
    };
    let mut grid = vec![vec![' '; cols]; rows + 1];
    for c in 0..cols {
        let i = c * t / cols;
        let (m, s, x) = (mean[i], std[i], target[i]);
        let (top, bot) = (map(m + 3.0 * s), map(m - 3.0 * s));
        for r in top.min(rows)..=bot.min(rows) {
            grid[r][c] = ':';
        }
        grid[map(m).min(rows)][c] = 'o';
        grid[map(x).min(rows)][c] = '.';
    }
    for row in grid {
        println!("  {}", row.into_iter().collect::<String>());
    }
}

fn main() {
    // The paper's best anomaly architecture: H=16, NL=2, B=YNYN.
    let cfg = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
    let (train, test) = data::anomaly_splits(0);
    println!("training {} on {} normal beats ...", cfg.name(), train.n);
    let mut trainer = NativeTrainer::new(
        cfg.clone(),
        TrainOpts { epochs: 120, batch: 64, lr: 1e-2, seed: 0 },
    );
    trainer.fit(&train);
    println!(
        "loss {:.4} -> {:.4}",
        trainer.loss_history[0],
        trainer.final_loss()
    );

    let s = 30;
    let mut pred = ModelPredictor::new(&trainer.model, 5);
    let normal_idx = (0..test.n).find(|&i| test.label(i) == 0).unwrap();
    let anom_idx = (0..test.n).find(|&i| test.label(i) == 1).unwrap();

    for (title, idx) in
        [("(a) normal ECG", normal_idx), ("(b) anomalous ECG", anom_idx)]
    {
        let beat = test.beat(idx);
        let out = pred.predict(beat, s);
        let (mean, std) = out.mean_std();
        let nll = metrics::gaussian_nll(beat, &mean, &std);
        let l1 = metrics::l1(&mean, beat);
        let rmse = metrics::rmse(&mean, beat);
        println!(
            "\n{title}:  NLL {nll:.2}  L1 {l1:.3}  RMSE {rmse:.3}  \
             (mean 3-sigma width {:.3})",
            std.iter().map(|v| 6.0 * v).sum::<f32>() / std.len() as f32
        );
        ascii_plot(beat, &mean, &std);
    }
    println!(
        "\nAs in Fig. 1: the model fits the normal beat tightly; on the \
         anomalous beat the fit degrades and the +/-3-sigma band inflates."
    );
}
