//! DSE walkthrough (Fig. 7 end to end): run a quick algorithmic sweep,
//! print the latency-vs-accuracy Pareto front, and show what each
//! optimisation mode would deploy — the interactive counterpart of
//! Tables V/VI.
//!
//!     cargo run --release --example dse_explore

use bayes_rnn_fpga::config::Task;
use bayes_rnn_fpga::dse::{LookupTable, Optimizer};
use bayes_rnn_fpga::hwmodel::ZC706;
use bayes_rnn_fpga::train::sweep::{self, SweepOpts};

fn main() {
    let task = Task::Classify;
    let opts = SweepOpts {
        epochs: 10,
        train_subset: 256,
        test_subset: 250,
        noise_subset: 25,
        mc_samples: 8,
        ..Default::default()
    };
    println!("sweeping the curated classification grid ...");
    let mut table = LookupTable::new();
    sweep::run(task, &opts, &mut table, |d, t, name| {
        println!("  [{d}/{t}] {name}");
    });

    let mut opt = Optimizer::new(&ZC706, &table);
    opt.batch = 50;
    opt.mc_samples = 30;

    println!("\nlatency-vs-accuracy Pareto front (batch 50, S per arch):");
    println!("{:<26} {:>12} {:>10}", "arch", "FPGA [ms]", "accuracy");
    for (arch, ms, acc) in opt.pareto_front(task, "accuracy") {
        println!("{:<26} {:>12.2} {:>10.3}", arch.name(), ms, acc);
    }

    println!("\nwhat each user priority deploys:");
    for mode in Optimizer::modes_for(task) {
        if let Some(c) = opt.optimize(task, mode) {
            println!(
                "  {:<14} -> {{{},{},{}}} R={{{},{},{}}} Q={} S={} \
                 ({:.2} ms, {:.0} DSPs, objective {:.3})",
                c.mode,
                c.arch.hidden,
                c.arch.nl,
                c.arch.bayes_str(),
                c.reuse.rx,
                c.reuse.rh,
                c.reuse.rd,
                c.precision.name(),
                c.s,
                c.fpga_latency_ms,
                c.resources.dsps,
                c.objective
            );
        }
    }
    println!(
        "\nAs in the paper: Opt-Latency trades quality for the smallest \
         pointwise S=1 design; quality modes deploy (partially) Bayesian \
         nets at 30 MC samples."
    );
}
