//! Quickstart: train a small Bayesian LSTM classifier on the synthetic
//! ECG5000 pool, "synthesise" it onto the FPGA simulator, and classify a
//! beat with uncertainty.
//!
//!     cargo run --release --example quickstart

use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::reuse_search;
use bayes_rnn_fpga::fpga::accel::Accelerator;
use bayes_rnn_fpga::fpga::pipeline::PipelineSim;
use bayes_rnn_fpga::hwmodel::{PowerModel, ZC706};
use bayes_rnn_fpga::train::{NativeTrainer, TrainOpts};

fn main() {
    // 1. An architecture point A = {H, NL, B}: 2 LSTM layers, MCD on the
    //    first (a partially-Bayesian net, Sec. II-B).
    let cfg = ArchConfig::new(Task::Classify, 8, 2, "YN");
    println!("architecture: {}  ({} weights)", cfg.name(), cfg.num_weights());

    // 2. Train with the paper's recipe (scaled-down epochs).
    let (train, test) = data::splits(0);
    let mut trainer = NativeTrainer::new(
        cfg.clone(),
        TrainOpts { epochs: 20, batch: 64, lr: 5e-3, seed: 0 },
    );
    trainer.fit(&train);
    println!(
        "trained: loss {:.4} -> {:.4}",
        trainer.loss_history[0],
        trainer.final_loss()
    );

    // 3. Hardware DSE: smallest II that fits the ZC706 DSP budget.
    let reuse = reuse_search(&cfg, &ZC706).expect("fits ZC706");
    let mut accel = Accelerator::new(&cfg, &trainer.model.params, reuse, 7);
    let res = accel.resources_synthesized();
    println!(
        "synthesised with R = {{x:{}, h:{}, d:{}}}  ->  {} DSPs \
         ({:.0}% of {}), {:.2} W",
        reuse.rx,
        reuse.rh,
        reuse.rd,
        res.dsps,
        res.dsps / ZC706.dsps as f64 * 100.0,
        ZC706.dsps,
        PowerModel::fpga_watts(&res),
    );

    // 4. Classify one beat with S = 30 MC-dropout samples.
    let s = 30;
    let beat = test.beat(0);
    let out = accel.predict(beat, s);
    let (mean, std) = out.mean_std();
    let lat = PipelineSim::new(&cfg, reuse).simulate_ms(1, s, ZC706.clock_hz);
    println!("\nbeat 0 (true class {}):", test.label(0));
    for k in 0..4 {
        println!("  class {k}: p = {:.3} +/- {:.3}", mean[k], std[k]);
    }
    println!("hardware latency @100 MHz: {lat:.3} ms for S={s} samples");
}
