//! Serving demo: the L3 coordinator drives a stream of ECG beats through
//! the FPGA-simulator engine (batch-1 streaming, as the paper deploys)
//! and through the analytic GPU baseline (batched), reporting
//! latency/throughput — a live miniature of Table IV.
//!
//!     cargo run --release --example serve_ecg

use std::time::Duration;

use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::coordinator::{BatchPolicy, Engine, Server, ServerConfig};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::reuse_search;
use bayes_rnn_fpga::hwmodel::ZC706;
use bayes_rnn_fpga::nn::model::Model;
use bayes_rnn_fpga::nn::Params;
use bayes_rnn_fpga::train::{NativeTrainer, TrainOpts};

fn main() {
    let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY"); // Table VI best
    let (train, test) = data::splits(0);
    println!("training {} ...", cfg.name());
    let mut trainer = NativeTrainer::new(
        cfg.clone(),
        TrainOpts { epochs: 15, batch: 64, lr: 5e-3, seed: 0 },
    );
    trainer.fit(&train);
    let params = trainer.model.params.tensors.clone();
    let s = 30;
    let n_req = 60;

    for engine_name in ["fpga-sim", "gpu-model"] {
        let cfg2 = cfg.clone();
        let p2 = params.clone();
        let en = engine_name.to_string();
        let policy = if engine_name == "fpga-sim" {
            BatchPolicy::stream()
        } else {
            BatchPolicy::batched(16, Duration::from_millis(2))
        };
        let mut server = Server::start(
            move || {
                let model =
                    Model::new(cfg2.clone(), Params { tensors: p2.clone() });
                if en == "fpga-sim" {
                    let reuse =
                        reuse_search(&cfg2, &ZC706).expect("fits ZC706");
                    Engine::fpga(&cfg2, &model, reuse, s, 3)
                } else {
                    Engine::gpu(model, s, 3)
                }
            },
            ServerConfig { policy, queue_depth: 128 },
        );
        let t0 = std::time::Instant::now();
        let receivers: Vec<_> = (0..n_req)
            .map(|i| server.submit(test.beat(i).to_vec()))
            .collect();
        let mut correct = 0;
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            let pred = resp
                .prediction
                .mean
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            if pred == test.label(i) as usize {
                correct += 1;
            }
        }
        let wall = t0.elapsed();
        let summary = server.join();
        println!(
            "\n[{engine_name}] served {} requests, S={s}, accuracy {:.2}",
            summary.served,
            correct as f64 / n_req as f64
        );
        println!(
            "  wall {:.2}s -> {:.1} req/s   batches {} (avg size {:.1})",
            wall.as_secs_f64(),
            summary.served as f64 / wall.as_secs_f64(),
            summary.batches,
            summary.mean_batch
        );
        println!(
            "  device-model latency: mean {:.2} ms  p99 {:.2} ms",
            summary.engine.mean_ms(),
            summary.engine.percentile_ms(99.0)
        );
    }
    println!(
        "\nThe FPGA design streams batch-1 requests at a fixed hardware \
         latency; the GPU baseline must batch to amortise launches and \
         still reports a far higher per-request device latency (Table IV)."
    );
}
