//! Fleet serving demo: the same trained Bayesian classifier behind 1 and
//! 4 FPGA-sim engines, under all three router policies — a miniature of
//! the `serve_fleet` bench harness with the MC-shard equivalence check
//! shown inline.
//!
//!     cargo run --release --example fleet_serve

use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::coordinator::{
    Engine, Fleet, FleetConfig, RouterPolicy, Ticket,
};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::reuse_search;
use bayes_rnn_fpga::hwmodel::ZC706;
use bayes_rnn_fpga::nn::model::Model;
use bayes_rnn_fpga::nn::Params;
use bayes_rnn_fpga::train::{NativeTrainer, TrainOpts};

const S: usize = 16;
const N_REQ: usize = 48;
const SEED: u64 = 3;

fn factories(
    n: usize,
    cfg: &ArchConfig,
    params: &[bayes_rnn_fpga::tensor::Tensor],
) -> Vec<Box<dyn FnOnce() -> Engine + Send + 'static>> {
    (0..n)
        .map(|_| {
            let c = cfg.clone();
            let p = params.to_vec();
            let f: Box<dyn FnOnce() -> Engine + Send + 'static> =
                Box::new(move || {
                    let reuse = reuse_search(&c, &ZC706).expect("fits ZC706");
                    let model =
                        Model::new(c.clone(), Params { tensors: p.clone() });
                    // One shared design seed => MC-shard determinism.
                    Engine::fpga(&c, &model, reuse, S, SEED)
                });
            f
        })
        .collect()
}

fn main() {
    let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY"); // Table VI best
    let (train, test) = data::splits(0);
    println!("training {} ...", cfg.name());
    let mut trainer = NativeTrainer::new(
        cfg.clone(),
        TrainOpts { epochs: 12, batch: 64, lr: 5e-3, seed: 0 },
    );
    trainer.fit(&train);
    let params = trainer.model.params.tensors.clone();

    let mut first_means: Vec<Vec<f32>> = Vec::new();
    for (engines, router) in [
        (1usize, RouterPolicy::RoundRobin),
        (4, RouterPolicy::RoundRobin),
        (4, RouterPolicy::LeastLoaded),
        (4, RouterPolicy::McShard),
    ] {
        let mut fleet = Fleet::start(
            FleetConfig {
                engines,
                router,
                samples: S,
                ..FleetConfig::default()
            },
            factories(engines, &cfg, &params),
        );
        let t0 = std::time::Instant::now();
        let tickets: Vec<Ticket> = (0..N_REQ)
            .filter_map(|i| fleet.submit(test.beat(i).to_vec()))
            .collect();
        let mut correct = 0;
        let mut first_mean = Vec::new();
        for (i, t) in tickets.into_iter().enumerate() {
            let resp = fleet.wait(t).expect("shard reply");
            if i == 0 {
                first_mean = resp.prediction.mean.clone();
            }
            let pred = resp
                .prediction
                .mean
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            if pred == test.label(i) as usize {
                correct += 1;
            }
        }
        let wall = t0.elapsed();
        let summary = fleet.join();
        println!(
            "\n[{engines} engine(s), {}] served {}  {:.1} req/s  \
             acc {:.2}  hw-model latency mean {:.2} ms",
            router.as_str(),
            summary.served,
            summary.served as f64 / wall.as_secs_f64(),
            correct as f64 / N_REQ as f64,
            summary.engine_stats().mean_ms()
        );
        first_means.push(first_mean);
    }

    // MC-shard (last run) must reproduce the single-engine prediction
    // for the same request id — the per-sample seeding invariant.
    let base = &first_means[0];
    let shard = first_means.last().unwrap();
    let max_delta = base
        .iter()
        .zip(shard)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "\nMC-shard vs single-engine first prediction: max |Δ| = \
         {max_delta:.2e} ({})",
        if max_delta < 1e-4 { "identical sample set" } else { "MISMATCH" }
    );
    println!(
        "MC-shard cuts per-request hardware latency ~Nx by splitting the \
         S={S} Monte-Carlo samples across engines; rr/least-loaded raise \
         request-level throughput instead."
    );
}
