//! END-TO-END driver: proves all three layers compose on a real workload.
//!
//! 1. Loads the AOT train-step artifact (L2 JAX fwd/bwd built on the L1
//!    Pallas cell kernel, lowered to HLO text by `make artifacts`).
//! 2. Trains the paper's best classifier (H=8, NL=3, B=YNY) from Rust
//!    through PJRT for a few hundred steps on the synthetic ECG corpus,
//!    logging the loss curve.
//! 3. Evaluates the trained weights on the test split (float + through
//!    the fixed-point FPGA simulator).
//! 4. Serves batched requests through the coordinator with the PJRT CPU
//!    engine and the FPGA-sim engine, reporting latency/throughput.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!
//! The observed run is recorded in EXPERIMENTS.md §End-to-end.

use std::path::Path;

use bayes_rnn_fpga::coordinator::{BatchPolicy, Engine, Server, ServerConfig};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::reuse_search;
use bayes_rnn_fpga::fpga::accel::Accelerator;
use bayes_rnn_fpga::hwmodel::ZC706;
use bayes_rnn_fpga::nn::model::Model;
use bayes_rnn_fpga::nn::Params;
use bayes_rnn_fpga::runtime::Runtime;
use bayes_rnn_fpga::train::eval::{eval_classify, ModelPredictor};
use bayes_rnn_fpga::train::PjrtTrainer;

fn main() -> anyhow::Result<()> {
    let arch = "classify_h8_nl3_YNY"; // Table VI's best architecture
    let artifacts = Path::new("artifacts");
    let epochs = 40; // 40 epochs x 8 steps = 320 PJRT train steps
    let batch = 64;

    // ---- 1+2: PJRT training through the AOT artifact ------------------
    let mut rt = Runtime::new(artifacts)?;
    println!("platform: {}", rt.platform());
    let (train, test) = data::splits(0);
    let mut trainer = PjrtTrainer::new(&mut rt, arch, batch, 3e-3, 0)?;
    let cfg = trainer.cfg.clone();
    println!(
        "training {arch} via PJRT train-step artifact: {} steps/epoch x \
         {epochs} epochs, batch {batch}",
        train.n.div_ceil(batch)
    );
    let t0 = std::time::Instant::now();
    for epoch in 0..epochs {
        trainer.fit(&train, 1)?;
        if epoch % 5 == 0 || epoch == epochs - 1 {
            println!(
                "  epoch {epoch:>3}  loss {:.4}  ({:.1}s)",
                trainer.loss_history.last().unwrap(),
                t0.elapsed().as_secs_f64()
            );
        }
    }
    let steps = trainer.loss_history.len();
    println!(
        "trained {steps} steps in {:.1}s  loss {:.4} -> {:.4}",
        t0.elapsed().as_secs_f64(),
        trainer.loss_history[0],
        trainer.loss_history.last().unwrap()
    );

    // ---- 3: evaluation (float + fixed-point FPGA sim) -----------------
    let params = trainer.params.clone();
    let model = Model::new(cfg.clone(), params.clone());
    let te = test.subset(&(0..400).collect::<Vec<_>>());
    let noise = data::gaussian_noise(40, 0);
    let s = 30;
    let mut fp = ModelPredictor::new(&model, 3);
    let float_rep = eval_classify(&mut fp, &te, &noise, s);
    println!(
        "\nfloat eval      : ACC {:.3}  AP {:.3}  AR {:.3}  H(noise) {:.3}",
        float_rep.accuracy, float_rep.ap, float_rep.ar,
        float_rep.noise_entropy
    );
    let reuse = reuse_search(&cfg, &ZC706).expect("fits ZC706");
    let mut accel = Accelerator::new(&cfg, &params, reuse, 3);
    let te_small = te.subset(&(0..150).collect::<Vec<_>>());
    let fixed_rep = eval_classify(&mut accel, &te_small, &noise, s);
    println!(
        "fixed-point eval: ACC {:.3}  AP {:.3}  AR {:.3}  H(noise) {:.3}  \
         (R = {{{},{},{}}})",
        fixed_rep.accuracy, fixed_rep.ap, fixed_rep.ar,
        fixed_rep.noise_entropy, reuse.rx, reuse.rh, reuse.rd
    );

    // ---- 4: serve batched requests -------------------------------------
    for engine_name in ["pjrt-cpu", "fpga-sim"] {
        let en = engine_name.to_string();
        let cfg2 = cfg.clone();
        let p2 = params.tensors.clone();
        let policy = if engine_name == "fpga-sim" {
            BatchPolicy::stream()
        } else {
            BatchPolicy::batched(8, std::time::Duration::from_millis(2))
        };
        let mut server = Server::start(
            move || {
                if en == "pjrt-cpu" {
                    let rt = Runtime::new(Path::new("artifacts"))
                        .expect("artifacts");
                    Engine::pjrt(rt, &cfg2.name(), &p2, s, 3)
                        .expect("pjrt engine")
                } else {
                    let model = Model::new(
                        cfg2.clone(),
                        Params { tensors: p2.clone() },
                    );
                    let reuse =
                        reuse_search(&cfg2, &ZC706).expect("fits ZC706");
                    Engine::fpga(&cfg2, &model, reuse, s, 3)
                }
            },
            ServerConfig { policy, queue_depth: 128 },
        );
        let n_req = 50;
        let t0 = std::time::Instant::now();
        let receivers: Vec<_> = (0..n_req)
            .map(|i| server.submit(test.beat(i).to_vec()))
            .collect();
        for rx in receivers {
            rx.recv()?;
        }
        let wall = t0.elapsed();
        let sm = server.join();
        println!(
            "\n[{engine_name}] {} reqs, S={s}: {:.1} req/s, e2e p50 \
             {:.2} ms p99 {:.2} ms, device-model mean {:.3} ms",
            sm.served,
            sm.served as f64 / wall.as_secs_f64(),
            sm.e2e.percentile_ms(50.0),
            sm.e2e.percentile_ms(99.0),
            sm.engine.mean_ms()
        );
    }
    println!("\ne2e OK: L1 Pallas kernel -> L2 JAX train/fwd -> AOT HLO -> \
              L3 Rust training, quantised FPGA sim, and serving all agree.");
    Ok(())
}
